"""§Roofline table: reads the dry-run records (experiments/dryrun/*.json)
and prints the per-(arch x shape x mesh) roofline terms, bottleneck,
MODEL_FLOPS ratio and the step-time lower bound.

Emits CSV:
arch,shape,mesh,step,compute_s,memory_s,collective_s,bottleneck,
model_flops_ratio,mfu_upper_bound

:func:`run_kernels` adds the kernel-pack view: every dispatchable MWU op
is pure streaming (O(1) flops/byte), so its roofline is the memory line
— achieved GB/s from the bytes-moved model under the pallas and XLA
paths, normalized to the best bandwidth any op achieved on this host.
"""
from __future__ import annotations

import json
from pathlib import Path

from .common import Csv

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run(tag_filter=""):
    csv = Csv(
        "arch,shape,mesh,step,compute_s,memory_s,collective_s,bottleneck,"
        "model_flops_ratio,mfu_upper_bound"
    )
    recs = []
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("tag", "") != tag_filter:
            continue
        recs.append(r)
    for r in recs:
        if r.get("skipped"):
            csv.add(r["arch"], r["shape"], r["mesh"], r.get("step", "-"),
                    "-", "-", "-", f"SKIP:{r['reason'][:40]}", "-", "-")
            continue
        if not r.get("ok"):
            csv.add(r["arch"], r["shape"], r["mesh"], r.get("step", "-"),
                    "-", "-", "-", f"FAIL:{r.get('error','?')[:40]}", "-", "-")
            continue
        ro = r["roofline"]
        csv.add(
            r["arch"], r["shape"], r["mesh"], r["step"],
            f"{ro['compute_s']:.3e}", f"{ro['memory_s']:.3e}",
            f"{ro['collective_s']:.3e}", ro["bottleneck"],
            f"{ro.get('model_flops_ratio', float('nan')):.3f}",
            f"{ro.get('mfu_upper_bound', float('nan')):.4f}",
        )
    csv.dump()
    return csv


def run_kernels(records=None, quick=True):
    """Memory-roofline view of the dispatchable MWU ops (pallas vs XLA).

    ``records`` takes the ``per_op`` list from ``bench_kernels`` so
    ``run.py kernels`` prints both views off one measurement pass; when
    absent the ops are (re)timed here.
    """
    if records is None:
        from . import bench_kernels

        records = bench_kernels.per_op_records([1 << 14] if quick else [1 << 16, 1 << 20])
    best = max((max(r["pallas_gbps"], r["xla_gbps"]) for r in records), default=1.0)
    csv = Csv("op,n,dtype,bytes,pallas_gbps,xla_gbps,pallas_frac_of_best")
    for r in records:
        csv.add(
            r["op"], r["n"], r["dtype"], r["bytes"],
            f"{r['pallas_gbps']:.3f}", f"{r['xla_gbps']:.3f}",
            f"{r['pallas_gbps'] / max(best, 1e-9):.3f}",
        )
    csv.dump()
    return csv
