"""§Roofline table: reads the dry-run records (experiments/dryrun/*.json)
and prints the per-(arch x shape x mesh) roofline terms, bottleneck,
MODEL_FLOPS ratio and the step-time lower bound.

Emits CSV:
arch,shape,mesh,step,compute_s,memory_s,collective_s,bottleneck,
model_flops_ratio,mfu_upper_bound
"""
from __future__ import annotations

import json
from pathlib import Path

from .common import Csv

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run(tag_filter=""):
    csv = Csv(
        "arch,shape,mesh,step,compute_s,memory_s,collective_s,bottleneck,"
        "model_flops_ratio,mfu_upper_bound"
    )
    recs = []
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("tag", "") != tag_filter:
            continue
        recs.append(r)
    for r in recs:
        if r.get("skipped"):
            csv.add(r["arch"], r["shape"], r["mesh"], r.get("step", "-"),
                    "-", "-", "-", f"SKIP:{r['reason'][:40]}", "-", "-")
            continue
        if not r.get("ok"):
            csv.add(r["arch"], r["shape"], r["mesh"], r.get("step", "-"),
                    "-", "-", "-", f"FAIL:{r.get('error','?')[:40]}", "-", "-")
            continue
        ro = r["roofline"]
        csv.add(
            r["arch"], r["shape"], r["mesh"], r["step"],
            f"{ro['compute_s']:.3e}", f"{ro['memory_s']:.3e}",
            f"{ro['collective_s']:.3e}", ro["bottleneck"],
            f"{ro.get('model_flops_ratio', float('nan')):.3f}",
            f"{ro.get('mfu_upper_bound', float('nan')):.4f}",
        )
    csv.dump()
    return csv
