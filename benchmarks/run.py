"""Benchmark entry point: one section per paper table/figure.

  table2  bench_solvers      MWU vs exact LP vs specialized algos
  table3  bench_stepsize     std / binary / newton step rules
  fig3    bench_convergence  MWU vs MPCSolver iteration counts
  fig5    bench_breakdown    component split + implicit-vs-explicit
  fig4    bench_scaling      DistSolver pod/data scaling vs device count
                             (writes BENCH_dist.json at the repo root)
  roofline bench_roofline    dry-run roofline table (§Roofline source)
  serving bench_serving      lpserve continuous batching vs sequential
  kernels bench_kernels      pallas kernel pack vs XLA, per op + solve
                             (writes BENCH_kernels.json at the repo root)
  tracecheck repro.tracecheck static jaxpr/HLO lint of the benched entry
                             points — the same family x backend x plan
                             matrix the CI gate sweeps (writes
                             TRACECHECK.json at the repo root)

``python -m benchmarks.run [section ...] [--quick]`` — default: all.
``--quick`` shrinks the kernels and fig4 sections to CI-smoke sizes. The solver
benches enable x64 (paper runs in f64 on CPU; DESIGN.md §7).
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

ALL_SECTIONS = [
    "table2", "table3", "fig3", "fig5", "fig4", "roofline", "serving", "kernels",
    "tracecheck",
]


def main() -> None:
    import jax

    jax.config.update("jax_enable_x64", True)

    argv = sys.argv[1:]
    quick = "--quick" in argv
    sections = [a for a in argv if not a.startswith("--")] or ALL_SECTIONS
    t00 = time.perf_counter()
    for s in sections:
        print(f"\n===== {s} =====", flush=True)
        t0 = time.perf_counter()
        if s == "table2":
            from . import bench_solvers

            bench_solvers.run(small=True)
        elif s == "table3":
            from . import bench_stepsize

            bench_stepsize.run(scale=12)
        elif s == "fig3":
            from . import bench_convergence

            bench_convergence.run()
        elif s == "fig5":
            from . import bench_breakdown

            bench_breakdown.run(scale=14)
        elif s == "fig4":
            from . import bench_scaling

            records = bench_scaling.run(quick=quick)
            out = Path(__file__).resolve().parents[1] / "BENCH_dist.json"
            out.write_text(json.dumps(records, indent=2) + "\n")
            print(f"wrote {out}", flush=True)
        elif s == "roofline":
            from . import bench_roofline

            bench_roofline.run()
        elif s == "serving":
            from . import bench_serving

            bench_serving.run()
        elif s == "kernels":
            from . import bench_kernels, bench_roofline

            records = bench_kernels.run(quick=quick)
            bench_roofline.run_kernels(records=records["per_op"])
            out = Path(__file__).resolve().parents[1] / "BENCH_kernels.json"
            out.write_text(json.dumps(records, indent=2) + "\n")
            print(f"wrote {out}", flush=True)
        elif s == "tracecheck":
            # the bench driver lints exactly the matrix the CI gate
            # sweeps (repro.tracecheck.matrix.default_matrix) — the
            # benched configurations and the linted ones cannot drift.
            from repro.tracecheck.cli import run_matrix

            root = Path(__file__).resolve().parents[1]
            out = root / "TRACECHECK.json"
            cm_out = root / "COSTMODEL.json"
            report = run_matrix(quick=quick, out=str(out), costmodel_out=str(cm_out))
            print(f"wrote {out} and {cm_out}", flush=True)
            if not report["ok"]:
                sys.exit(1)
        else:
            print(f"unknown section {s}")
        print(f"[{s}: {time.perf_counter()-t0:.1f}s]", flush=True)
    print(f"\n[total: {time.perf_counter()-t00:.1f}s]", flush=True)


if __name__ == "__main__":
    main()
