"""Table 2 analogue: MWU-opt vs exact LP (HiGHS plays CPLEX/Gurobi) vs
specialized algorithms (scipy Hopcroft-Karp plays ms-bfs-graft; Charikar
peel plays GBBS) on the synthetic graph suite, eps = 0.1.

Emits CSV: problem,graph,algo,seconds,value,relerr_vs_exact.
"""
from __future__ import annotations

import time

from repro.api import MWUOptions, Solver
from repro.graphs import baselines, build
from repro.graphs.problems import bmatching_lp

from .common import Csv, graph_suite, timed

OPTS = MWUOptions(eps=0.1, step_rule="newton", max_iter=20000)
# sequential = the paper's binary search; batched = speculative bracket
# evaluation, batch_width bounds per vmapped XLA call (repro.api)
SOLVER_SEQ = Solver(OPTS, batch_width=1)
SOLVER_BATCH = Solver(OPTS, batch_width=4)


def run(small=True):
    csv = Csv("problem,graph,algo,seconds,value,relerr_vs_exact")
    suite = graph_suite(small)
    for gname, g in suite.items():
        for problem in ["match", "vcover", "dom-set", "dense-sub"]:
            try:
                exact, t_exact = baselines.exact_lp(problem, g)
            except Exception as e:  # pragma: no cover
                exact, t_exact = float("nan"), float("nan")
            lp = build(problem, g)
            res, t_mwu = timed(SOLVER_SEQ.solve, lp)
            val = res.bound if problem == "dense-sub" else res.objective
            rel = abs(val - exact) / max(abs(exact), 1e-12)
            csv.add(problem, gname, "mwu-opt", f"{t_mwu:.3f}", f"{val:.4f}", f"{rel:.4f}")
            resb, t_b = timed(SOLVER_BATCH.solve, lp)
            valb = resb.bound if problem == "dense-sub" else resb.objective
            relb = abs(valb - exact) / max(abs(exact), 1e-12)
            csv.add(problem, gname, "mwu-batch4", f"{t_b:.3f}", f"{valb:.4f}", f"{relb:.4f}")
            csv.add(problem, gname, "exact-highs", f"{t_exact:.3f}", f"{exact:.4f}", 0.0)
            # specialized baselines
            if problem == "match":
                t0 = time.perf_counter()
                gm = baselines.greedy_maximal_matching(g)
                csv.add(problem, gname, "greedy", f"{time.perf_counter()-t0:.3f}", gm,
                        f"{abs(gm-exact)/max(exact,1e-12):.4f}")
            if problem == "dense-sub":
                (rho, size), t_ch = timed(lambda: baselines.charikar_peel(g))
                csv.add(problem, gname, "charikar-gbbs", f"{t_ch:.3f}", f"{rho:.4f}",
                        f"{abs(rho-exact)/max(exact,1e-12):.4f}")
            if problem == "dom-set":
                ds, t_ds = timed(lambda: baselines.greedy_dominating_set(g))
                csv.add(problem, gname, "greedy-setcover", f"{t_ds:.3f}", ds,
                        f"{abs(ds-exact)/max(exact,1e-12):.4f}")
    # bipartite matching vs Hopcroft-Karp
    from repro.graphs import bipartite_ratings

    g = bipartite_ratings(3000, 1500, avg_ratings=14.0, seed=0)
    exact, t_hk = timed(lambda: baselines.hopcroft_karp_bmatch(g))
    lp = bmatching_lp(g)
    res, t_mwu = timed(SOLVER_SEQ.solve, lp)
    csv.add("bmatch", "ratings-3k", "mwu-opt", f"{t_mwu:.3f}", f"{res.objective:.2f}",
            f"{abs(res.objective-exact)/exact:.4f}")
    csv.add("bmatch", "ratings-3k", "hopcroft-karp", f"{t_hk:.3f}", exact, 0.0)
    csv.dump()
    return csv
