"""Pallas kernel pack vs plain XLA, per op and per end-to-end solve.

Times each dispatchable hot op (incidence gather, softmax weights,
line-search probe, fused axpy) under ``impl="pallas"`` and ``impl="xla"``
at one or two sizes, then solves whole problems with
``MWUOptions(kernel_backend=...)`` both ways and checks via
``dispatch.stats()`` that the pallas path was genuinely active.

On CPU the pallas timings run the kernels through the Pallas interpreter
(pure XLA lowering of the tiled kernel body) — they measure dispatch
correctness and tiling overhead, not Mosaic speed; on a real TPU the
same records become the fused-vs-unfused comparison. Records are
returned as a JSON-ready dict; ``benchmarks/run.py kernels`` writes them
to BENCH_kernels.json.

Emits CSV: op,n,dtype,pallas_us,xla_us,xla_over_pallas
      and: family,backend,solve_s,feasible,ops_on_pallas
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch as kd
from repro.kernels.axpy_reduce.ops import axpy_reduce
from repro.kernels.incidence_gather.ops import incidence_gather
from repro.kernels.linesearch_probe.ops import linesearch_probe
from repro.kernels.softmax_weights.ops import softmax_weights

from .common import Csv

FAMILIES = ["match", "vcover", "dom-set", "dense-sub"]


def _time_us(fn, *args, repeats=10):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6


def _op_bytes(op: str, n: int, itemsize: int) -> int:
    """Streaming-bytes model for the roofline view (reads + writes)."""
    if op == "gather":
        # u, v int32 reads + random w reads (~1 line each) + E-sized write
        return n * (4 + 4 + 2 * itemsize)
    if op == "softmax":
        return n * 2 * itemsize  # read v, write weights
    if op == "probe":
        return n * 2 * itemsize  # read y, dy; scalar outputs
    if op == "axpy":
        return n * 3 * itemsize  # read y, dy; write out
    raise ValueError(op)


def per_op_records(sizes, dtype=jnp.float64):
    rng = np.random.default_rng(0)
    recs = []
    itemsize = jnp.dtype(dtype).itemsize
    for n in sizes:
        y = jnp.asarray(rng.random(n), dtype)
        dy = jnp.asarray(rng.random(n) * 1e-3, dtype)
        u = jnp.asarray(rng.integers(0, n, n), jnp.int32)
        v = jnp.asarray(rng.integers(0, n, n), jnp.int32)
        eta = jnp.asarray(150.0, dtype)
        alpha = jnp.asarray(2.5, dtype)
        calls = {
            "gather": lambda impl: incidence_gather(u, v, y, impl=impl),
            "softmax": lambda impl: softmax_weights(y, eta, sign=1.0, impl=impl),
            "probe": lambda impl: linesearch_probe(y, dy, alpha, eta, sign=-1.0, impl=impl),
            "axpy": lambda impl: axpy_reduce(y, dy, alpha, impl=impl),
        }
        for op, call in calls.items():
            t_p = _time_us(call, "pallas")
            t_x = _time_us(call, "xla")
            b = _op_bytes(op, n, itemsize)
            recs.append(
                {
                    "op": op,
                    "n": n,
                    "dtype": jnp.dtype(dtype).name,
                    "pallas_us": round(t_p, 2),
                    "xla_us": round(t_x, 2),
                    "xla_over_pallas": round(t_x / max(t_p, 1e-9), 3),
                    "bytes": b,
                    "pallas_gbps": round(b / max(t_p, 1e-9) / 1e3, 3),
                    "xla_gbps": round(b / max(t_x, 1e-9) / 1e3, 3),
                }
            )
    return recs


def end_to_end_records(families, scale=5):
    from repro.api import MWUOptions, Solver
    from repro.graphs import build, grid2d

    g = grid2d(scale)
    recs = []
    for family in families:
        prob = build(family, g)
        for backend in ["xla", "pallas"]:
            opts = MWUOptions(
                eps=0.15, step_rule="newton", max_iter=20000, kernel_backend=backend
            )
            solver = Solver(opts, batch_width=4)
            # dispatch decisions happen at trace time: read the stats off
            # the compiling call, then time the warm (cached) one
            kd.reset_stats()
            sol = solver.solve(prob)
            s = kd.stats()
            t0 = time.perf_counter()
            sol = solver.solve(prob)
            dt = time.perf_counter() - t0
            on_pallas = sorted(op for op, d in s.items() if d["pallas"] > 0)
            recs.append(
                {
                    "family": family,
                    "backend": backend,
                    "graph": g.name,
                    "solve_s": round(dt, 4),
                    "feasible": bool(sol.feasible),
                    "objective": float(sol.objective),
                    "bound": float(sol.bound),
                    "ops_on_pallas": on_pallas,
                    "stats": s,
                }
            )
    return recs


def dispatch_active(e2e_recs) -> bool:
    """Every pallas-backend solve ran softmax+probe+axpy (and gather where
    the family has a gather-shaped operator) on the kernel path."""
    ok = True
    for r in e2e_recs:
        need = {"softmax", "probe", "axpy"}
        if r["backend"] == "pallas" and r["family"] != "dom-set":
            need.add("gather")
        if r["backend"] == "pallas" and not need.issubset(set(r["ops_on_pallas"])):
            ok = False
    return ok


def run(quick=False):
    sizes = [1 << 14] if quick else [1 << 16, 1 << 20]
    families = ["match", "dense-sub"] if quick else FAMILIES
    policy = kd.resolve("pallas")

    per_op = per_op_records(sizes)
    csv = Csv("op,n,dtype,pallas_us,xla_us,xla_over_pallas")
    for r in per_op:
        csv.add(r["op"], r["n"], r["dtype"], r["pallas_us"], r["xla_us"], r["xla_over_pallas"])
    csv.dump()

    e2e = end_to_end_records(families, scale=4 if quick else 6)
    csv2 = Csv("family,backend,solve_s,feasible,ops_on_pallas")
    for r in e2e:
        csv2.add(
            r["family"], r["backend"], r["solve_s"], r["feasible"],
            "+".join(r["ops_on_pallas"]) or "-",
        )
    csv2.dump()

    active = dispatch_active(e2e)
    print(f"dispatch_active={active} (pallas policy: interpret={policy.interpret})")
    return {
        "platform": jax.default_backend(),
        "interpret": policy.interpret,
        "quick": bool(quick),
        "dispatch_active": active,
        "per_op": per_op,
        "end_to_end": e2e,
    }
