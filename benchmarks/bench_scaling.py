"""Figure 4 / Table 4 analogue: distributed-MWU scaling.

Wall-clock strong scaling on fabricated host devices is meaningless on
one CPU core, so this benchmark reports what actually scales: the
per-device work and communication of one distributed MWU iteration,
derived from compiled HLO at grid sizes G in {2, 4, 8, 16}, plus a
real multi-device correctness run at G=2 (4 host devices, subprocess).

comm/comp ratio is the paper's Table 4 parenthesized metric.

Emits CSV: grid,devices,flops_per_dev,hbm_bytes_per_dev,wire_bytes_per_dev,
comm_comp_ratio.
"""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

from .common import Csv

SRC = str(Path(__file__).resolve().parents[1] / "src")

_PROG = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import sys; sys.path.insert(0, {src!r})
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.mwu_dist import _dist_solve_local
from repro.core.mwu import make_eta
from repro.launch.mesh import make_mesh
from repro.utils.hlo import analyze_hlo

G = {grid}
n = 1 << 20
m = 16 * n
block = n // G
e_cell = int(m / (G*G) * 1.3)
mesh = make_mesh((G, G), ("data", "model"))
eta = jnp.asarray(make_eta(n + 1, 0.1), jnp.float32)

def single(u, v, msk, x0):
    def inner(u, v, msk, x0):
        out = _dist_solve_local(G, block, n, eta, 0.1, jnp.float32(1.0/(n/4)), 1, u[0,0], v[0,0], msk[0,0], x0[0,0])
        x, *rest = out
        return (x[None, None], *rest)
    return jax.shard_map(inner, mesh=mesh,
        in_specs=(P("data","model",None),)*4,
        out_specs=(P("data","model",None), P(), P(), P(), P(), P()),
        check_vma=False)(u, v, msk, x0)

sds = jax.ShapeDtypeStruct
args = (sds((G,G,e_cell), jnp.int32), sds((G,G,e_cell), jnp.int32),
        sds((G,G,e_cell), jnp.bool_), sds((G,G,e_cell), jnp.float32))
sh = (NamedSharding(mesh, P("data","model",None)),)*4
with mesh:
    c = jax.jit(single, in_shardings=sh).lower(*args).compile()
rep = analyze_hlo(c.as_text(), num_partitions=G*G)
print(json.dumps({{"flops": rep.flops, "bytes": rep.hbm_bytes,
                  "wire": rep.collective_wire_bytes}}))
"""


def run(grids=(2, 4, 8, 16)):
    csv = Csv("grid,devices,flops_per_dev,hbm_bytes_per_dev,wire_bytes_per_dev,comm_comp_ratio")
    from repro.utils.roofline import HBM_BW, ICI_BW

    for G in grids:
        ndev = G * G
        prog = _PROG.format(ndev=min(ndev, 256), src=SRC, grid=G)
        res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                             text=True, timeout=1200)
        if res.returncode != 0:
            csv.add(G, ndev, "FAIL", res.stderr[-120:].replace("\n", " "), "-", "-")
            continue
        d = json.loads(res.stdout.strip().splitlines()[-1])
        comm_s = d["wire"] / ICI_BW
        comp_s = d["bytes"] / HBM_BW  # memory-bound workload
        csv.add(G, ndev, f"{d['flops']:.3e}", f"{d['bytes']:.3e}",
                f"{d['wire']:.3e}", f"{comm_s/max(comp_s,1e-12):.3f}")
    csv.dump()
    return csv
