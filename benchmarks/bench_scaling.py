"""Figure 4 / Table 4 analogue: distributed-MWU scaling on repro.dist.

Strong scaling of the mesh-sharded :class:`repro.dist.DistSolver` over
fabricated host devices (``--xla_force_host_platform_device_count``).
Each device count runs in its own subprocess (the main process keeps one
device), solving the same problem two ways:

* ``pod=N``  edge-slab matching feasibility — the paper's MPI edge
  partition: each device owns E/N incidence rows, psum is the neighbor
  exchange. Reports MWU iteration throughput (iters/s, wall).
* ``data=N`` batched bound fan-out — N binary-search probes solved as
  one shard_map launch, one lane per device. Reports lane throughput
  (lane-iters/s).

Fabricated devices share one CPU, so wall-clock *speedup* is not
expected; what the numbers certify is that per-device work shrinks with
pod (iters/s should not collapse as N grows) and that the data axis
fans out at near-constant cost per lane.

``run()`` prints the CSV and returns the records dict that
``benchmarks/run.py`` serializes to ``BENCH_dist.json``.
"""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

from .common import Csv

SRC = str(Path(__file__).resolve().parents[1] / "src")

_PROG = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import sys; sys.path.insert(0, {src!r})
import json, time
import numpy as np
from repro.core.mwu import MWUOptions
from repro.dist import DistSolver, MeshPlan
from repro.graphs.generators import rgg
from repro.graphs.problems import matching_lp

g = rgg({scale}, seed=7)
prob = matching_lp(g)
opts = MWUOptions(eps=0.1, max_iter={max_iter})
rec = {{"devices": {ndev}, "n_vertices": g.n, "n_edges": g.m}}

# pod=N: edge-slab sharded feasibility (the paper's partition scheme)
solver = DistSolver(opts, plan=MeshPlan(pod={ndev}, data=1))
r = solver.feasible(prob, prob.lo)          # compile
t0 = time.perf_counter(); r = solver.feasible(prob, prob.lo)
dt = time.perf_counter() - t0
it = int(np.asarray(r.iters))
rec["pod"] = {{"iters": it, "seconds": dt, "iters_per_s": it / max(dt, 1e-9),
               "status": int(np.asarray(r.status)),
               "psum_rounds": solver.dist_stats["psum_rounds"]}}

# data=N: one probe per device, a full binary-search fan-out in 1 launch
bounds = list(np.linspace(prob.lo, prob.hi, {ndev}))
solver = DistSolver(opts, plan=MeshPlan(pod=1, data={ndev}))
res = solver.solve_batch(prob, bounds)      # compile
t0 = time.perf_counter(); res = solver.solve_batch(prob, bounds)
dt = time.perf_counter() - t0
lane_it = int(np.asarray(res.iters).sum())
rec["data"] = {{"lanes": {ndev}, "lane_iters": lane_it, "seconds": dt,
                "lane_iters_per_s": lane_it / max(dt, 1e-9),
                "feasible_lanes": int(np.asarray(res.feasible).sum())}}
print(json.dumps(rec))
"""


def run(quick: bool = False):
    """Benchmark DistSolver across device counts; returns the records dict."""
    counts = (1, 2, 4) if quick else (1, 2, 4, 8)
    scale = 10 if quick else 12
    max_iter = 300 if quick else 2000
    csv = Csv(
        "devices,pod_iters_per_s,pod_psum_rounds,data_lane_iters_per_s,data_feasible_lanes"
    )
    records = {"bench": "dist_scaling", "quick": quick, "scale": scale,
               "max_iter": max_iter, "per_devices": []}
    for ndev in counts:
        prog = _PROG.format(ndev=ndev, src=SRC, scale=scale, max_iter=max_iter)
        res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                             text=True, timeout=1800)
        if res.returncode != 0:
            csv.add(ndev, "FAIL", res.stderr[-120:].replace("\n", " "), "-", "-")
            records["per_devices"].append({"devices": ndev, "error": res.stderr[-2000:]})
            continue
        d = json.loads(res.stdout.strip().splitlines()[-1])
        records["per_devices"].append(d)
        csv.add(ndev, f"{d['pod']['iters_per_s']:.1f}", d["pod"]["psum_rounds"],
                f"{d['data']['lane_iters_per_s']:.1f}", d["data"]["feasible_lanes"])
    csv.dump()
    return records
