"""Shared benchmark utilities: timing, graph suite, CSV emission."""
from __future__ import annotations

import time



def timed(fn, *args, repeats=1, **kw):
    """(result, seconds) — min over repeats, first call includes jit."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def graph_suite(small=True):
    """CPU-budget version of the paper's Table 1 inputs."""
    from repro.graphs import kron, rgg

    if small:
        scales_rgg = [10, 12]
        scales_kron = [9, 11]
    else:
        scales_rgg = [12, 14, 16]
        scales_kron = [11, 13]
    gs = {}
    for s in scales_rgg:
        gs[f"rgg-{s}"] = rgg(s, seed=s)
    for s in scales_kron:
        gs[f"kron-{s}"] = kron(s, seed=s, edgefactor=8)
    return gs


class Csv:
    def __init__(self, header):
        self.rows = [header]

    def add(self, *vals):
        self.rows.append(",".join(str(v) for v in vals))

    def dump(self):
        for r in self.rows:
            print(r, flush=True)
