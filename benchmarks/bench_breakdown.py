"""Figure 5 analogue: per-component timing + implicit-vs-explicit speedup.

(a) Where does MWU iteration time go? matvec (P/C SpMV pairs) vs
    line-search probes vs remaining vector work — microbenchmarked on a
    mid-solve state.
(b) The paper's §5.1.2 claim: implicit incidence operators beat the
    explicit generic-sparse representation (our Coo = the PETSc role).
    Reported as per-component speedup, like Fig. 5c / Table 4's
    shared-memory half.
(c) Kernel dispatch: the same hot ops and an end-to-end solve with the
    Pallas kernel pack forced on (``kernel_backend="pallas"``, interpret
    mode off-TPU) vs the default XLA path, proving the dispatch layer is
    active and measuring what it costs/saves on this platform.

Emits CSV: problem,component,implicit_us,explicit_us,speedup
      and: component,pallas_us,xla_us,xla_over_pallas
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Coo, Incidence, MWUOptions
from repro.core.mwu import make_eta
from repro.core.smoothing import smax_and_weights
from repro.core.stepsize import binary_search_step, make_probe_fn
from repro.graphs import rgg
from repro.kernels import dispatch as kd

from .common import Csv


def _time(fn, *a, n=20):
    fn(*a)  # compile
    jax.block_until_ready(fn(*a))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def coo_of_incidence(g):
    rows = jnp.concatenate([jnp.asarray(g.u), jnp.asarray(g.v)]).astype(jnp.int32)
    cols = jnp.tile(jnp.arange(g.m, dtype=jnp.int32), 2)
    vals = jnp.ones((2 * g.m,))
    return Coo(rows=rows, cols=cols, vals=vals, _shape=(g.n, g.m))


def run(scale=14):
    g = rgg(scale, seed=scale)
    csv = Csv("problem,component,implicit_us,explicit_us,speedup")
    imp = Incidence(u=jnp.asarray(g.u), v=jnp.asarray(g.v), n_vertices=g.n)
    exp = coo_of_incidence(g)

    rng = np.random.default_rng(0)
    xe = jnp.asarray(rng.random(g.m))
    wv = jnp.asarray(rng.random(g.n))

    mv_i = _time(jax.jit(imp.matvec), xe)
    mv_e = _time(jax.jit(exp.matvec), xe)
    csv.add("match", "matvec", f"{mv_i:.1f}", f"{mv_e:.1f}", f"{mv_e/mv_i:.2f}")
    rmv_i = _time(jax.jit(imp.rmatvec), wv)
    rmv_e = _time(jax.jit(exp.rmatvec), wv)
    csv.add("match", "matvec_T", f"{rmv_i:.1f}", f"{rmv_e:.1f}", f"{rmv_e/rmv_i:.2f}")

    # vec work (gradients + step direction) and search probes on a
    # representative state
    eta = jnp.asarray(make_eta(g.n + 1, 0.1))
    y = jnp.asarray(rng.random(g.n) * 0.5)
    z = jnp.asarray(rng.random(16) * 0.5)
    dy = jnp.asarray(rng.random(g.n) * 1e-3)
    dz = jnp.asarray(rng.random(16) * 1e-3)
    x0 = jnp.asarray(rng.random(g.m) * 1e-3)

    def vec_work(y, x0, gvec):
        _, wp = smax_and_weights(y, eta)
        d = 0.5 / eta * jnp.maximum(0.0, 1.0 - gvec) * x0
        return d

    gv = jnp.asarray(rng.random(g.m))
    t_vec = _time(jax.jit(vec_work), y, x0, gv)
    t_search = _time(
        jax.jit(lambda *a: binary_search_step(*a).alpha), y, z, dy, dz, eta
    )
    csv.add("match", "vec", f"{t_vec:.1f}", "-", "-")
    csv.add("match", "search", f"{t_search:.1f}", "-", "-")
    csv.add("match", "matvec_pair", f"{mv_i + rmv_i:.1f}", "-", "-")

    # end-to-end implicit vs explicit solve (the Fig. 5c headline)
    from repro.core import OnesRow, solve
    from repro.graphs.baselines import greedy_maximal_matching

    gm = max(greedy_maximal_matching(g), 1)
    opts = MWUOptions(eps=0.1, step_rule="newton", max_iter=20000)
    C1 = OnesRow(c=jnp.ones((g.m,)), inv_bound=jnp.asarray(1.0 / gm))

    def solve_with(op):
        return solve(op, C1, opts)

    r_imp = solve_with(imp)  # compile + run
    t0 = time.perf_counter()
    r_imp = jax.block_until_ready(solve_with(imp))
    t_imp = time.perf_counter() - t0
    r_exp = solve_with(exp)
    t0 = time.perf_counter()
    r_exp = jax.block_until_ready(solve_with(exp))
    t_exp = time.perf_counter() - t0
    assert int(r_imp.status) == int(r_exp.status)
    csv.add("match", "end2end_solve", f"{t_imp*1e6:.0f}", f"{t_exp*1e6:.0f}",
            f"{t_exp/max(t_imp,1e-9):.2f}")
    csv.dump()

    # (c) kernel dispatch: pallas pack vs XLA on the same mid-solve state.
    # Dispatch decisions are trace-time, so each jit wrapper is traced
    # (compiled) under its policy; the timed calls then hit that cache.
    pallas = kd.resolve("pallas")

    def _time_under(policy, fn, *a):
        f = jax.jit(fn)
        kd.reset_stats()
        with kd.use_policy(policy):
            jax.block_until_ready(f(*a))
        chosen = kd.stats()
        return _time(f, *a), chosen

    csv2 = Csv("component,pallas_us,xla_us,xla_over_pallas")
    alpha0 = jnp.asarray(0.5)
    for name, fn, a in [
        ("rmatvec_dispatch", imp.rmatvec, (wv,)),
        ("smax_weights_dispatch", lambda v: smax_and_weights(v, eta)[1], (y,)),
        ("probe_dispatch", lambda aa: make_probe_fn(y, z, dy, dz, eta)(aa).f, (alpha0,)),
    ]:
        (t_p, chosen), (t_x, _) = _time_under(pallas, fn, *a), _time_under(kd.XLA_POLICY, fn, *a)
        on = "+".join(op for op, d in chosen.items() if d["pallas"] > 0) or "FALLBACK"
        csv2.add(f"{name}[{on}]", f"{t_p:.1f}", f"{t_x:.1f}", f"{t_x/max(t_p,1e-9):.2f}")

    opts_p = dataclasses.replace(opts, kernel_backend="pallas")
    r_p = solve(imp, C1, opts_p)  # compile under the pallas policy
    t0 = time.perf_counter()
    r_p = jax.block_until_ready(solve(imp, C1, opts_p))
    t_p = time.perf_counter() - t0
    assert int(r_p.status) == int(r_imp.status)
    csv2.add("end2end_solve_dispatch", f"{t_p*1e6:.0f}", f"{t_imp*1e6:.0f}",
             f"{t_imp/max(t_p,1e-9):.2f}")
    csv2.dump()
    return csv
