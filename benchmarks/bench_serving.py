"""Serving throughput: continuous-batching LPEngine vs sequential solve.

The serving analogue of Table 2: a mixed-size matching/vcover workload
(three graph size tiers per family) solved (a) request-by-request with
``Solver.solve`` and (b) through ``repro.lpserve.LPEngine``'s bucketed
lane batching. Emits CSV:

  workload,algo,requests,seconds,req_per_s,batches,probes,occupancy,waste
"""
from __future__ import annotations

import time

from repro.api import MWUOptions, Solver
from repro.graphs import build, erdos
from repro.lpserve import LPEngine, LPServeConfig

from .common import Csv

OPTS = MWUOptions(eps=0.1, step_rule="newton", max_iter=20000)


def _workload(families: list[str], requests: int, scale: int):
    tiers = [(40 * scale, 110 * scale), (60 * scale, 170 * scale), (90 * scale, 260 * scale)]
    probs = []
    for i in range(requests):
        n, m = tiers[i % len(tiers)]
        probs.append(build(families[i % len(families)], erdos(n, m, seed=i)))
    return probs


def run(requests: int = 24, lanes: int = 8, scale: int = 1):
    csv = Csv("workload,algo,requests,seconds,req_per_s,batches,probes,occupancy,waste")
    for wname, families in [("match", ["match"]), ("mixed", ["match", "vcover"])]:
        probs = _workload(families, requests, scale)

        solver = Solver(OPTS, batch_width=1)
        t0 = time.perf_counter()
        seq = [solver.solve(p) for p in probs]
        t_seq = time.perf_counter() - t0
        probes = sum(s.feasibility_calls for s in seq)
        csv.add(wname, "sequential", requests, f"{t_seq:.3f}",
                f"{requests / t_seq:.2f}", probes, probes, 1.0, 0.0)

        engine = LPEngine(LPServeConfig(opts=OPTS, lanes=lanes))
        t0 = time.perf_counter()
        sols = engine.solve_many(probs)
        t_eng = time.perf_counter() - t0
        st = engine.stats()
        assert all(s.feasible for s in sols)
        csv.add(wname, f"lpserve-lanes{lanes}", requests, f"{t_eng:.3f}",
                f"{requests / t_eng:.2f}", st["batches"], st["feasibility_calls"],
                st["lane_occupancy"], st["padding_waste"])
    csv.dump()
    return csv
