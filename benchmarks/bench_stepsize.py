"""Table 3 analogue: step-size strategies on one rgg graph.

Std vs binary-search vs Newton: MWU iterations, avg line-search probes
per iteration, wall time — the paper's headline 10^2-10^3x iteration
reduction from the step-size search contribution.

Emits CSV: problem,strategy,mwu_iters,avg_probes,seconds,value.
"""
from __future__ import annotations

from repro.core import MWUOptions
from repro.graphs import build, rgg

from .common import Csv, timed


def run(scale=12, std_max_iter=40000):
    g = rgg(scale, seed=scale)
    csv = Csv("problem,strategy,mwu_iters,avg_probes,seconds,value")
    for problem in ["match", "vcover", "dom-set", "dense-sub"]:
        lp = build(problem, g)
        for rule in ["std", "binary", "newton"]:
            opts = MWUOptions(
                eps=0.1, step_rule=rule,
                max_iter=std_max_iter if rule == "std" else 20000,
            )
            res, secs = timed(lp.solve, opts)
            iters = max(res.mwu_iters_total, 1)
            val = res.bound if problem == "dense-sub" else res.objective
            csv.add(
                problem, rule, res.mwu_iters_total,
                f"{res.ls_probes_total / iters:.2f}", f"{secs:.3f}", f"{val:.4f}",
            )
    csv.dump()
    return csv
