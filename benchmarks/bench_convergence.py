"""Figure 3 analogue: generalized matching — MWU (std / Newton) vs
MPCSolver (gradient descent with adaptive error, Makari et al.).

Synthetic ratings bipartite graph (Appendix A.2 structure: user lower
bound 3 / upper 5; item upper bound 200; users with >= 10 ratings).
Compares ITERATION counts to reach max violation <= eps, like the paper
(both methods share the per-iteration SpMV pair).

Emits CSV: algo,iters_to_eps,final_violation + the violation curve tail.
"""
from __future__ import annotations

import numpy as np

from repro.core import MPCOptions, MWUOptions, mpc_solve, solve_traced
from repro.graphs import bipartite_ratings, generalized_matching_lp

from .common import Csv


def build_instance(n_users=1500, n_items=700, seed=0):
    g = bipartite_ratings(n_users, n_items, avg_ratings=18.0, seed=seed)
    deg = g.degrees()
    s = g.bipartite_split
    lb = np.zeros(g.n)
    ub = np.ones(g.n)
    lb[:s] = np.minimum(3, deg[:s])
    ub[:s] = 5
    ub[s:] = 200
    return g, generalized_matching_lp(g, lb, ub)


def iters_to(viol, eps):
    idx = np.nonzero(viol <= eps)[0]
    return int(idx[0]) if len(idx) else -1


def run(eps=0.05, max_iter=6000):
    g, (P, C, c_mask) = build_instance()
    csv = Csv("algo,iters_to_eps,final_violation")

    res_n, tr_n = solve_traced(
        P, C, MWUOptions(eps=eps, step_rule="newton", max_iter=max_iter), c_mask=c_mask
    )
    csv.add("mwu-newton", iters_to(tr_n["max_violation"], eps),
            f"{tr_n['max_violation'][-1]:.4f}")

    res_s, tr_s = solve_traced(
        P, C, MWUOptions(eps=eps, step_rule="std", max_iter=max_iter), c_mask=c_mask
    )
    csv.add("mwu-std", iters_to(tr_s["max_violation"], eps),
            f"{tr_s['max_violation'][-1]:.4f}")

    x, tr_g = mpc_solve(P, C, MPCOptions(eps=eps, max_iter=max_iter), c_mask=c_mask)
    csv.add("mpcsolver-gd", iters_to(tr_g["max_violation"], eps),
            f"{tr_g['max_violation'][-1]:.4f}")
    csv.dump()
    return csv, {"newton": tr_n, "std": tr_s, "mpc": tr_g}
