"""End-to-end Table-2-style run over a configurable graph suite.

    PYTHONPATH=src python examples/graph_lp_suite.py [--scale 12] [--rule newton]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

from repro.core import MWUOptions
from repro.graphs import baselines, build, kron, rgg

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=int, default=12)
ap.add_argument("--rule", default="newton", choices=["std", "binary", "newton"])
ap.add_argument("--eps", type=float, default=0.1)
args = ap.parse_args()

import time

for gname, g in [(f"rgg-{args.scale}", rgg(args.scale)),
                 (f"kron-{args.scale-2}", kron(args.scale - 2, edgefactor=8))]:
    print(f"\n== {gname}: |V|={g.n} |E|={g.m} ==")
    for problem in ["match", "vcover", "dom-set", "dense-sub"]:
        lp = build(problem, g)
        t0 = time.perf_counter()
        res = lp.solve(MWUOptions(eps=args.eps, step_rule=args.rule))
        dt = time.perf_counter() - t0
        val = res.bound if problem == "dense-sub" else res.objective
        print(f"{problem:10s} value={val:10.3f} time={dt:6.2f}s "
              f"iters={res.mwu_iters_total} feas_calls={res.feasibility_calls}")
