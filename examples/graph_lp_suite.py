"""End-to-end Table-2-style run over a configurable graph suite.

    PYTHONPATH=src python examples/graph_lp_suite.py [--scale 12] [--rule newton] [--batch 4]

--batch K > 1 evaluates K binary-search bounds per vmapped feasibility
call (speculative bracket evaluation); --batch 1 reproduces the paper's
sequential search.
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

from repro.api import MWUOptions, Solver
from repro.graphs import build, kron, rgg

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=int, default=12)
ap.add_argument("--rule", default="newton", choices=["std", "binary", "newton"])
ap.add_argument("--eps", type=float, default=0.1)
ap.add_argument("--batch", type=int, default=4, help="bounds per vmapped feasibility call")
args = ap.parse_args()

solver = Solver(MWUOptions(eps=args.eps, step_rule=args.rule), batch_width=args.batch)

for gname, g in [(f"rgg-{args.scale}", rgg(args.scale)),
                 (f"kron-{args.scale-2}", kron(args.scale - 2, edgefactor=8))]:
    print(f"\n== {gname}: |V|={g.n} |E|={g.m} ==")
    for problem in ["match", "vcover", "dom-set", "dense-sub"]:
        prob = build(problem, g)
        t0 = time.perf_counter()
        sol = solver.solve(prob)
        dt = time.perf_counter() - t0
        val = sol.bound if problem == "dense-sub" else sol.objective
        print(f"{problem:10s} value={val:10.3f} time={dt:6.2f}s "
              f"iters={sol.mwu_iters_total} feas_calls={sol.feasibility_calls}")
