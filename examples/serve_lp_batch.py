"""Serving demo: mixed-size graph-LP traffic through repro.lpserve.

    PYTHONPATH=src python examples/serve_lp_batch.py [--requests 12] [--lanes 8]

Heterogeneous requests (different graph sizes, multiple LP families) go
through the :class:`repro.lpserve.LPEngine`: each problem is padded into
its shape bucket via ``edge_mask``, bucket lanes are continuously
refilled from the queue, and every dispatch round drives ONE vmapped
``Solver.solve_batch`` per bucket — one compiled shape per (family,
bucket) serving every request that lands there. Compare with the old
version of this example, which required every request to share one
padded-by-construction shape.

The script doubles as the CI serving smoke test: it asserts every
request returns a feasible certified Solution that matches the
sequential ``Solver.solve`` objective, and that batching actually
happened (fewer batches than feasibility calls).
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

from repro.api import MWUOptions, Solver
from repro.graphs import build, erdos
from repro.lpserve import LPEngine, LPServeConfig

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=12)
ap.add_argument("--lanes", type=int, default=8)
ap.add_argument("--eps", type=float, default=0.1)
ap.add_argument("--families", default="match,vcover")
args = ap.parse_args()

# three size tiers -> mixed-shape traffic (the thing the engine exists for)
SIZE_TIERS = [(40, 100), (60, 160), (80, 220)]
families = args.families.split(",")
probs = []
for i in range(args.requests):
    n, m = SIZE_TIERS[i % len(SIZE_TIERS)]
    probs.append(build(families[i % len(families)], erdos(n, m, seed=i)))

opts = MWUOptions(eps=args.eps, step_rule="newton")
engine = LPEngine(LPServeConfig(opts=opts, lanes=args.lanes))

t0 = time.perf_counter()
sols = engine.solve_many(probs)
t_engine = time.perf_counter() - t0

solver = Solver(opts, batch_width=1)
t0 = time.perf_counter()
refs = [solver.solve(p) for p in probs]
t_seq = time.perf_counter() - t0

stats = engine.stats()
print(f"{args.requests} mixed-size requests ({', '.join(families)}; "
      f"tiers {SIZE_TIERS})")
print(f"engine    : {t_engine:6.2f}s  ({stats['batches']} batches, "
      f"{stats['feasibility_calls']} probes, "
      f"occupancy {stats['lane_occupancy']:.0%}, "
      f"padding waste {stats['padding_waste']:.0%})")
print(f"sequential: {t_seq:6.2f}s  (per-request binary search)")
print(f"compiles  : {stats['compiles']} "
      f"(+{stats['compile_cache_hits']} cache hits); "
      f"latency p50 {stats['latency_p50_s']:.2f}s p99 {stats['latency_p99_s']:.2f}s")
for key, b in stats["buckets"].items():
    print(f"  bucket {key:20s} requests={b['requests']:3d} batches={b['batches']:3d} "
          f"occupancy={b['lane_occupancy']:.0%} waste={b['padding_waste']:.0%}")

# smoke contract (the CI serving step relies on these asserts)
for i, (p, sol, ref) in enumerate(zip(probs, sols, refs)):
    assert sol.feasible, f"request {i} ({p.name} on {p.graph.name}): not feasible"
    rel = abs(sol.objective - ref.objective) / max(abs(ref.objective), 1e-12)
    assert rel <= 3.0 * args.eps, (
        f"request {i}: engine objective {sol.objective:.4f} deviates "
        f"{rel:.3f} from sequential {ref.objective:.4f}"
    )
    print(f"  request {i:2d}: {p.name:7s} {p.graph.name:8s} "
          f"obj={sol.objective:8.3f} (seq {ref.objective:8.3f}) "
          f"calls={sol.feasibility_calls}")
assert stats["batches"] < stats["feasibility_calls"], "batching never kicked in"
print("all requests feasible; engine objectives match sequential solve")
