"""Serving-style fan-out: many graph LP requests through one vmapped solve.

    PYTHONPATH=src python examples/serve_lp_batch.py [--requests 8]

The serving story for the LP engine mirrors serve/engine.py's slot
batching for LMs: independent requests (same problem family, same
padded shape) are tree-stacked into one batched Problem and the MWU
while_loop runs across all of them in a single XLA call — one
compilation, one dispatch, N answers. Here each "request" is a matching
LP on an independent random graph; production would pad edge lists with
``edge_mask`` to a common shape bucket.
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.api import MWUOptions, Solver, Status, stack_problems
from repro.graphs import build, erdos

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=8)
ap.add_argument("--n", type=int, default=400)
ap.add_argument("--m", type=int, default=1200)
args = ap.parse_args()

solver = Solver(MWUOptions(eps=0.1, step_rule="newton"))

# one matching "request" per client; erdos pads/subsamples to exactly m
# edges so every instance shares the batch shape
probs = [build("match", erdos(args.n, args.m, seed=s)) for s in range(args.requests)]
stacked = stack_problems(probs)
bounds = jnp.asarray([np.sqrt(float(p.lo) * float(p.hi)) for p in probs])

t0 = time.perf_counter()
batch = solver.solve_batch(stacked, bounds, batched_problem=True)
jax.block_until_ready(batch.x)
t_batch = time.perf_counter() - t0

t0 = time.perf_counter()
seq = [solver.feasible(p, float(b)) for p, b in zip(probs, bounds)]
t_seq = time.perf_counter() - t0

print(f"{args.requests} matching requests on er({args.n},{args.m}) graphs")
print(f"batched : {t_batch:6.2f}s  (one vmapped XLA call)")
print(f"looped  : {t_seq:6.2f}s  (per-request dispatch, shared jit cache)")
status = np.asarray(batch.status)
for j in range(args.requests):
    ok = "feasible" if status[j] == Status.FEASIBLE else "infeasible"
    print(f"  request {j}: bound={float(bounds[j]):8.2f} {ok} "
          f"iters={int(np.asarray(batch.iters)[j])}")
