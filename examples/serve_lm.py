"""Deliverable (b): batched serving with KV caches + slot batching.

    PYTHONPATH=src python examples/serve_lm.py
"""
from dataclasses import replace

import jax
import numpy as np

from repro.configs import get
from repro.serve.engine import Engine, ServeConfig

cfg = replace(
    get("mixtral-8x22b").reduced(), name="mixtral-tiny", sliding_window=32,
)
eng = Engine(cfg, ServeConfig(max_len=128, slots=4, temperature=0.8))
eng.load(eng.model.init(jax.random.PRNGKey(0)))

rng = np.random.default_rng(0)
prompts = [rng.integers(1, cfg.vocab_size, size=rng.integers(4, 12)) for _ in range(6)]
outs = eng.generate(prompts, max_new=16)
for i, (p, o) in enumerate(zip(prompts, outs)):
    print(f"req{i}: prompt[{len(p)} toks] -> {o}")
print("served", len(prompts), "requests in slot-batched decode")
