"""Quickstart: solve four graph LPs with MWU in ~30 seconds (CPU).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import MWUOptions
from repro.graphs import baselines, build, rgg

g = rgg(11, seed=0)
print(f"graph: rgg-11  |V|={g.n} |E|={g.m}")
opts = MWUOptions(eps=0.1, step_rule="newton")
for problem in ["match", "vcover", "dom-set", "dense-sub"]:
    lp = build(problem, g)
    res = lp.solve(opts)
    exact, _ = baselines.exact_lp(problem, g)
    val = res.bound if problem == "dense-sub" else res.objective
    print(
        f"{problem:10s} mwu={val:10.3f} exact={exact:10.3f} "
        f"rel={abs(val-exact)/max(exact,1e-12):6.3f} "
        f"iters={res.mwu_iters_total:5d} probes={res.ls_probes_total}"
    )
