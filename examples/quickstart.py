"""Quickstart: solve four graph LPs through the repro.api facade (~30 s CPU).

    PYTHONPATH=src python examples/quickstart.py

One declarative Problem per LP, one Solver for all of them; batch_width
controls how many binary-search bounds are evaluated per vmapped XLA
call (1 = the paper's sequential search).
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.api import MWUOptions, Solver
from repro.graphs import baselines, build, rgg

g = rgg(11, seed=0)
print(f"graph: rgg-11  |V|={g.n} |E|={g.m}")
solver = Solver(MWUOptions(eps=0.1, step_rule="newton"), batch_width=4)
for problem in ["match", "vcover", "dom-set", "dense-sub"]:
    sol = solver.solve(build(problem, g))
    exact, _ = baselines.exact_lp(problem, g)
    val = sol.bound if problem == "dense-sub" else sol.objective
    print(
        f"{problem:10s} mwu={val:10.3f} exact={exact:10.3f} "
        f"rel={abs(val-exact)/max(exact,1e-12):6.3f} "
        f"iters={sol.mwu_iters_total:5d} probes={sol.ls_probes_total} "
        f"calls={sol.feasibility_calls}"
    )
