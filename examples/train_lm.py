"""Deliverable (b): train a ~100M-param LM for a few hundred steps on CPU
with the full substrate (data pipeline, AdamW, checkpoints, restart).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses a scaled-down minitron-family config (~100M params with the 256k
vocab embedding dominating, per the pool family) and asserts the loss
drops; kill it mid-run and re-run to see checkpoint resume in action.
"""
import argparse
from dataclasses import replace

from repro.configs import get
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
args = ap.parse_args()

cfg = replace(
    get("minitron-4b"),
    n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_head=32, d_ff=1024,
    vocab_size=32768, remat="none", dtype="float32", name="minitron-100m",
)
tc = TrainerConfig(
    steps=args.steps, seq_len=256, global_batch=8, ckpt_dir=args.ckpt,
    ckpt_every=50, log_every=10,
    opt=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
)
t = Trainer(cfg, tc)
import numpy as np
import jax

n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(t.model.init(jax.random.PRNGKey(0))))
print(f"training {cfg.name}: {n_params/1e6:.1f}M params, {args.steps} steps")
t.run()
print(f"final loss: {t.last_metrics['loss']:.4f}; slow steps flagged: {t.slow_steps}")
