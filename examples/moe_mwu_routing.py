"""The paper's technique INSIDE the model: MWU LP router vs top-k.

Builds a skewed routing distribution and shows the MWU router flattening
expert load under capacity constraints (fewer dropped tokens).

    PYTHONPATH=src python examples/moe_mwu_routing.py
"""
import jax.numpy as jnp
import numpy as np

from repro.models.layers.moe import expert_load, mwu_route, topk_route

rng = np.random.default_rng(0)
T, E, k = 512, 8, 2
logits = jnp.asarray(rng.standard_normal((T, E)) * 0.2)
logits = logits.at[:, 0].add(3.0).at[:, 1].add(2.0)  # hot experts
cap = int(T * k / E * 1.25)

idx_t, _ = topk_route(logits, k)
idx_m, _ = mwu_route(logits, k, cap, mwu_iters=64)
lt = np.asarray(expert_load(idx_t, E))
lm = np.asarray(expert_load(idx_m, E))
print(f"capacity/expert: {cap}")
print(f"top-k   load: {lt}  dropped={np.maximum(lt-cap,0).sum()}")
print(f"mwu-lp  load: {lm}  dropped={np.maximum(lm-cap,0).sum()}")
assert np.maximum(lm - cap, 0).sum() <= np.maximum(lt - cap, 0).sum()
print("MWU router respects capacities better (same LP solver as the graph problems)")
