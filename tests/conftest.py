"""Shared test configuration.

x64 is enabled for the whole test session: the MWU solver's oracle tests
compare against scipy in f64, and model code pins its own dtypes
explicitly (f32/bf16) so it is unaffected.

NOTE: tests intentionally see exactly ONE device — the multi-device
distributed tests spawn subprocesses with their own XLA_FLAGS, per the
dry-run isolation rule.
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest

from repro.graphs import Graph, erdos, grid2d, kron, rgg


@pytest.fixture(scope="session")
def small_graphs():
    """A diverse bag of small graphs used across solver tests."""
    return {
        "grid6": grid2d(6),
        "rgg10": rgg(10, seed=1),
        "kron8": kron(8, seed=2, edgefactor=8),
        "er": erdos(200, 600, seed=3),
        "path": Graph.from_edges(5, np.array([[0, 1], [1, 2], [2, 3], [3, 4]]), "path5"),
        "star": Graph.from_edges(6, np.array([[0, i] for i in range(1, 6)]), "star6"),
        "triangle": Graph.from_edges(3, np.array([[0, 1], [1, 2], [0, 2]]), "tri"),
    }
