"""Layer-level correctness: chunked-vs-dense attention, SSD-vs-recurrence,
RG-LRU scan-vs-loop, decode-vs-forward consistency, MoE dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.models import Model
from repro.models.layers import attention as att
from repro.models.layers import mamba2 as m2
from repro.models.layers import rglru as rg
from repro.models.layers import moe as moemod


def test_chunked_attention_matches_dense():
    rng = np.random.default_rng(0)
    B, S, Hq, Hkv, dh = 2, 37, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, Hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    pos = jnp.arange(S)
    for causal, window in [(True, None), (True, 9), (False, None)]:
        dense = att._sdpa_dense(q, k, v, pos[None].repeat(B, 0), pos, causal=causal, window=window)
        chunk = att._sdpa_chunked(q, k, v, pos, pos, causal=causal, window=window,
                                  q_block=8, kv_block=8)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(chunk), atol=2e-5,
                                   err_msg=f"causal={causal} window={window}")


def test_decode_matches_forward_attention():
    """Autoregressive decode through the cache must equal the parallel
    forward pass position-by-position (dense arch)."""
    cfg = get("yi-34b").reduced()
    model = Model(cfg, fsdp=False)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    h = model.forward(params, {"tokens": toks})
    full_logits = model.logits(params, h)

    caches = model.init_caches(B, S)
    outs = []
    for t in range(S):
        lg, caches = model.decode_step(params, caches, toks[:, t : t + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full_logits), atol=2e-3, rtol=2e-3
    )


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "recurrentgemma-9b", "mamba2-1.3b"])
def test_decode_matches_forward_other_families(arch):
    from dataclasses import replace

    cfg = get(arch).reduced()
    if cfg.moe is not None:
        # decode routes 2 tokens/step while forward routes all 24 at once:
        # capacity dropping would (correctly) differ — disable drops here.
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    model = Model(cfg, fsdp=False)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    h = model.forward(params, {"tokens": toks})
    full_logits = model.logits(params, h)
    caches = model.init_caches(B, max(S, 16))
    outs = []
    for t in range(S):
        lg, caches = model.decode_step(params, caches, toks[:, t : t + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full_logits), atol=5e-3, rtol=5e-3
    )


def test_ssd_matches_naive_recurrence():
    """Chunked SSD == sequential h_t = exp(dt A) h + dt B x recurrence."""
    rng = np.random.default_rng(0)
    B, S, H, Pd, G, N = 2, 23, 4, 8, 2, 6
    x = jnp.asarray(rng.standard_normal((B, S, H, Pd)), jnp.float32)
    dt = jnp.asarray(rng.random((B, S, H)) * 0.5 + 0.05, jnp.float32)
    A = jnp.asarray(np.log(rng.random(H) * 4 + 0.5), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, G, N)), jnp.float32)

    y_chunk, h_last = m2._ssd_chunked(x, dt, A, Bm, Cm, chunk=5)

    # naive oracle
    rep = H // G
    Bh = np.repeat(np.asarray(Bm), rep, axis=2)
    Ch = np.repeat(np.asarray(Cm), rep, axis=2)
    h = np.zeros((B, H, N, Pd))
    ys = np.zeros((B, S, H, Pd))
    a = -np.exp(np.asarray(A))
    for t in range(S):
        decay = np.exp(a[None, :] * np.asarray(dt)[:, t])  # (B,H)
        upd = np.einsum("bhn,bhp->bhnp", Bh[:, t], np.asarray(x)[:, t] * np.asarray(dt)[:, t][..., None])
        h = h * decay[..., None, None] + upd
        ys[:, t] = np.einsum("bhn,bhnp->bhp", Ch[:, t], h)
    np.testing.assert_allclose(np.asarray(y_chunk), ys, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last), h, atol=1e-4, rtol=1e-4)


def test_rglru_scan_matches_loop():
    cfg = get("recurrentgemma-9b").reduced()
    params = rg.rglru_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S, d = 2, 17, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.3
    out_scan, _ = rg.rglru_apply(params, x, cfg)

    # token-by-token decode oracle
    state = rg.init_rglru_state(cfg, B)
    outs = []
    for t in range(S):
        o, state = rg.rglru_decode(params, x[:, t : t + 1], cfg, state)
        outs.append(o)
    out_loop = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_scan), np.asarray(out_loop), atol=2e-4, rtol=2e-4)


def test_sliding_window_ring_cache():
    """SWA decode with a ring cache == decode with a full cache."""
    from dataclasses import replace

    cfg = get("mixtral-8x22b").reduced()  # window 16
    # disable MoE capacity drops: forward routes all 24 tokens at once,
    # decode routes 1/step — drop behaviour would (correctly) differ
    cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    model = Model(cfg, fsdp=False)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 24  # exceeds the 16-slot ring
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    h = model.forward(params, {"tokens": toks})
    full_logits = model.logits(params, h)
    caches = model.init_caches(B, 64)  # ring clamps to window=16
    assert caches.scanned[0].k.shape[2] == cfg.sliding_window
    outs = []
    for t in range(S):
        lg, caches = model.decode_step(params, caches, toks[:, t : t + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits), atol=5e-3, rtol=5e-3)


def test_moe_dispatch_no_drop_equals_dense_eval():
    """With generous capacity, the sorted dispatch must compute exactly
    gate-weighted expert outputs (oracle: loop over experts)."""
    from dataclasses import replace

    cfg = get("mixtral-8x22b").reduced()
    cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    params = moemod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.3
    out = moemod.moe_apply(params, x, cfg)

    # oracle
    T = B * S
    xt = x.reshape(T, -1)
    logits = xt @ params["router"]
    idx, gate = moemod.topk_route(logits, cfg.moe.top_k)
    y = np.zeros((T, cfg.d_model), np.float32)
    for e in range(cfg.moe.n_experts):
        he = jax.nn.silu(xt @ params["wg"][e]) * (xt @ params["wu"][e])
        oe = np.asarray(he @ params["wd"][e])
        for kk in range(cfg.moe.top_k):
            sel = np.asarray(idx[:, kk]) == e
            y[sel] += np.asarray(gate[:, kk])[sel, None] * oe[sel]
    np.testing.assert_allclose(np.asarray(out.reshape(T, -1)), y, atol=2e-4, rtol=2e-4)


def test_mwu_router_respects_capacity_better():
    """The MWU LP router must flatten expert load vs plain top-k on a
    skewed router distribution (the paper's technique inside the model)."""
    rng = np.random.default_rng(0)
    T, E, k = 256, 8, 2
    # heavily skewed affinities: everyone loves experts 0/1
    logits = jnp.asarray(rng.standard_normal((T, E)) * 0.1)
    logits = logits.at[:, 0].add(3.0).at[:, 1].add(2.5)
    cap = int(T * k / E * 1.25)
    idx_top, _ = moemod.topk_route(logits, k)
    idx_mwu, _ = moemod.mwu_route(logits, k, cap, mwu_iters=64)
    load_top = np.asarray(moemod.expert_load(idx_top, E))
    load_mwu = np.asarray(moemod.expert_load(idx_mwu, E))
    assert load_mwu.max() <= load_top.max(), (load_top, load_mwu)
    # dropped-token count under capacity
    drop_top = np.maximum(load_top - cap, 0).sum()
    drop_mwu = np.maximum(load_mwu - cap, 0).sum()
    assert drop_mwu <= drop_top
