"""Roofline HLO analyzer vs closed-form expectations on known programs."""
import jax
import jax.numpy as jnp

from repro.utils.hlo import analyze_hlo


def test_single_matmul_flops_exact():
    m, k, n = 128, 256, 64
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
    ).compile()
    rep = analyze_hlo(c.as_text())
    assert rep.dot_flops == 2 * m * k * n
    # bytes: at least read A + read B + write C
    assert rep.hbm_bytes >= 4 * (m * k + k * n + m * n)


def test_scan_trip_count_multiplies():
    L = 9

    def f(x, ws):
        def body(c, w):
            return c @ w, None

        out, _ = jax.lax.scan(body, x, ws)
        return out

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((L, 64, 64), jnp.float32),
    ).compile()
    rep = analyze_hlo(c.as_text())
    assert rep.dot_flops == L * 2 * 32 * 64 * 64
    assert any(t == L for t in rep.while_trips.values())


def test_scan_weight_slices_not_overcharged():
    """The stacked (L, 64, 64) weights must be charged per-slice, not
    full-buffer per iteration."""
    L = 16

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None

        out, _ = jax.lax.scan(body, x, ws)
        return out

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, 64), jnp.float32),
        jax.ShapeDtypeStruct((L, 64, 64), jnp.float32),
    ).compile()
    rep = analyze_hlo(c.as_text())
    full_buffer_per_iter = L * (L * 64 * 64 * 4)  # the overcount trap
    assert rep.hbm_bytes < full_buffer_per_iter


_SYNTHETIC_WHILE_HLO = """\
HloModule synth

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %it = s32[] get-tuple-element((s32[], f32[8]) %p), index=0
  %junk = s32[] constant(999999)
  %k = s32[] constant(7)
  ROOT %lt = pred[] compare(s32[] %it, s32[] %k), direction=LT
}

%body (q: (s32[], f32[8])) -> (s32[], f32[8]) {
  %q = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8]) %q), index=0
  %v = f32[8] get-tuple-element((s32[], f32[8]) %q), index=1
  %one = s32[] constant(1)
  %i1 = s32[] add(s32[] %i, s32[] %one)
  %v2 = f32[8] add(f32[8] %v, f32[8] %v)
  ROOT %t = (s32[], f32[8]) tuple(s32[] %i1, f32[8] %v2)
}

ENTRY %main (x: f32[8]) -> (s32[], f32[8]) {
  %x = f32[8] parameter(0)
  %z = s32[] constant(0)
  %c0 = (s32[], f32[8]) tuple(s32[] %z, f32[8] %x)
  ROOT %w = (s32[], f32[8]) while((s32[], f32[8]) %c0), condition=%cond, body=%body
}
"""


def test_trip_count_ignores_unrelated_constants():
    """Regression: the old heuristic took the max int literal anywhere in
    the condition, so %junk = constant(999999) inflated trips 142857x.
    Only constants feeding the loop-bound compare may count."""
    rep = analyze_hlo(_SYNTHETIC_WHILE_HLO)
    assert rep.while_trips == {"w": 7}


_NESTED_WHILE_HLO = """\
HloModule nested

%inner_cond (ip: (s32[], f32[8])) -> pred[] {
  %ip = (s32[], f32[8]) parameter(0)
  %ij = s32[] get-tuple-element((s32[], f32[8]) %ip), index=0
  %ik = s32[] constant(3)
  ROOT %ilt = pred[] compare(s32[] %ij, s32[] %ik), direction=LT
}

%inner_body (iq: (s32[], f32[8])) -> (s32[], f32[8]) {
  %iq = (s32[], f32[8]) parameter(0)
  %ii = s32[] get-tuple-element((s32[], f32[8]) %iq), index=0
  %iv = f32[8] get-tuple-element((s32[], f32[8]) %iq), index=1
  %ione = s32[] constant(1)
  %ii1 = s32[] add(s32[] %ii, s32[] %ione)
  %iv2 = f32[8] multiply(f32[8] %iv, f32[8] %iv)
  ROOT %it = (s32[], f32[8]) tuple(s32[] %ii1, f32[8] %iv2)
}

%outer_cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %j = s32[] get-tuple-element((s32[], f32[8]) %p), index=0
  %k = s32[] constant(5)
  ROOT %lt = pred[] compare(s32[] %j, s32[] %k), direction=LT
}

%outer_body (q: (s32[], f32[8])) -> (s32[], f32[8]) {
  %q = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8]) %q), index=0
  %v = f32[8] get-tuple-element((s32[], f32[8]) %q), index=1
  %one = s32[] constant(1)
  %i1 = s32[] add(s32[] %i, s32[] %one)
  %zero = s32[] constant(0)
  %ic0 = (s32[], f32[8]) tuple(s32[] %zero, f32[8] %v)
  %iw = (s32[], f32[8]) while((s32[], f32[8]) %ic0), condition=%inner_cond, body=%inner_body
  %v2 = f32[8] get-tuple-element((s32[], f32[8]) %iw), index=1
  ROOT %t = (s32[], f32[8]) tuple(s32[] %i1, f32[8] %v2)
}

ENTRY %main (x: f32[8]) -> (s32[], f32[8]) {
  %x = f32[8] parameter(0)
  %z = s32[] constant(0)
  %c0 = (s32[], f32[8]) tuple(s32[] %z, f32[8] %x)
  ROOT %w = (s32[], f32[8]) while((s32[], f32[8]) %c0), condition=%outer_cond, body=%outer_body
}
"""


def test_nested_while_trip_counts():
    """Each loop's bound comes from its own condition, and the nested
    body's work multiplies through both (5 outer x 3 inner)."""
    from repro.tracecheck.hlo_ir import parse_hlo, trip_count, while_ops

    mod = parse_hlo(_NESTED_WHILE_HLO)
    by_cond = {w["cond"]: w for w in while_ops(mod)}
    assert trip_count(mod.comps, "outer_cond") == 5
    assert trip_count(mod.comps, "inner_cond") == 3
    assert by_cond["outer_cond"]["top_level"]
    assert not by_cond["inner_cond"]["top_level"]
    rep = analyze_hlo(_NESTED_WHILE_HLO)
    assert rep.while_trips == {"w": 5, "iw": 3}
    # the inner multiply (8 elements, 1 flop/element estimate when fused;
    # here unfused so charged via hbm bytes) runs 15 times: check bytes
    assert rep.hbm_bytes >= 5 * 3 * (3 * 8 * 4)  # 15x read+read+write of f32[8]


def test_dynamic_while_trip_count_is_none():
    """A condition comparing two loop-carried values has no recoverable
    bound: trip_count must return None, not a fabricated 1."""
    from repro.tracecheck.hlo_ir import parse_hlo, trip_count

    hlo = """\
HloModule dynamic

%cond (p: (s32[], s32[])) -> pred[] {
  %p = (s32[], s32[]) parameter(0)
  %a = s32[] get-tuple-element((s32[], s32[]) %p), index=0
  %b = s32[] get-tuple-element((s32[], s32[]) %p), index=1
  ROOT %lt = pred[] compare(s32[] %a, s32[] %b), direction=LT
}

%body (q: (s32[], s32[])) -> (s32[], s32[]) {
  %q = (s32[], s32[]) parameter(0)
  %i = s32[] get-tuple-element((s32[], s32[]) %q), index=0
  %j = s32[] get-tuple-element((s32[], s32[]) %q), index=1
  %one = s32[] constant(1)
  ROOT %t = (s32[], s32[]) tuple(s32[] add(s32[] %i, s32[] %one), s32[] %j)
}

ENTRY %main (x: s32[], y: s32[]) -> (s32[], s32[]) {
  %x = s32[] parameter(0)
  %y = s32[] parameter(1)
  %c0 = (s32[], s32[]) tuple(s32[] %x, s32[] %y)
  ROOT %w = (s32[], s32[]) while((s32[], s32[]) %c0), condition=%cond, body=%body
}
"""
    mod = parse_hlo(hlo)
    assert trip_count(mod.comps, "cond") is None
    # the analyzer falls back to counting the body once, not crashing
    rep = analyze_hlo(hlo)
    assert rep.while_trips == {"w": None}


def test_collective_wire_formula():
    import subprocess, sys, json, textwrap
    from pathlib import Path

    src = str(Path(__file__).resolve().parents[1] / "src")
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp, json
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import make_mesh
        from repro.utils.hlo import analyze_hlo
        mesh = make_mesh((4,), ("model",))
        def f(x, w):
            return x @ w  # contraction sharded -> all-reduce f32[128,128]
        xs = NamedSharding(mesh, P(None, "model"))
        ws = NamedSharding(mesh, P("model", None))
        with mesh:
            c = jax.jit(f, in_shardings=(xs, ws)).lower(
                jax.ShapeDtypeStruct((128, 256), jnp.float32),
                jax.ShapeDtypeStruct((256, 128), jnp.float32)).compile()
        rep = analyze_hlo(c.as_text(), num_partitions=4)
        print(json.dumps({{"wire": rep.collective_wire_bytes,
                          "n": rep.n_collectives}}))
    """)
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    d = json.loads(res.stdout.strip().splitlines()[-1])
    # one AR of f32[128,128]: 2*(4-1)/4 * 65536 = 98304 wire bytes
    assert d["n"] >= 1
    assert abs(d["wire"] - 2 * 3 / 4 * 128 * 128 * 4) / (2 * 3 / 4 * 128 * 128 * 4) < 0.5
