"""repro.api facade: golden parity vs legacy drivers + batched execution.

Covers the unified Solver/Problem surface: (a) Solution objectives agree
with the legacy binary-search drivers (now shims) and with exact LP
values within the (1+eps) certificate band, (b) ``solve_batch`` vmaps
feasibility calls across bounds in one XLA call and agrees with the
sequential loop, (c) instance batching over tree-stacked Problems,
(d) the io_callback trace hook, (e) Problem pytree mechanics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import MWUOptions, Problem, Solution, Solver, Status, stack_problems
from repro.core import OnesRow, solve
from repro.core.feasibility import (
    densest_subgraph_search,
    maximize_packing,
    minimize_covering,
)
from repro.graphs import Graph, baselines, build, erdos, generalized_matching_lp
from repro.graphs.problems import generalized_matching_problem

EPS = 0.1
OPTS = MWUOptions(eps=EPS, step_rule="newton", max_iter=20000)


# ---------------------------------------------------------------- pytree --
def test_problem_pytree_roundtrip(small_graphs):
    prob = build("match", small_graphs["triangle"])
    leaves, treedef = jax.tree_util.tree_flatten(prob)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.name == prob.name
    assert back.sense == prob.sense
    assert back.bound_mode == prob.bound_mode
    assert back.n_vars == prob.n_vars
    assert float(back.lo) == float(prob.lo)
    # host-only metadata must NOT leak into jit cache keys
    assert back.graph is None
    np.testing.assert_array_equal(np.asarray(back.P.u), np.asarray(prob.P.u))


def test_problem_validation():
    with pytest.raises(ValueError):
        Problem(name="x", kind="packing", sense="bogus", bound_mode="none")
    with pytest.raises(ValueError):
        Problem(name="x", kind="packing", sense="max", bound_mode="bogus")
    prob = Problem(name="x", kind="packing", sense="max", bound_mode="objective_covering",
                   P=None, c=jnp.ones((3,)))
    with pytest.raises(ValueError):
        prob.instantiate(None)  # bound required for objective modes


# ------------------------------------------------- golden parity vs shims --
@pytest.mark.parametrize("problem", ["match", "vcover", "dom-set", "dense-sub"])
def test_solver_matches_legacy_and_exact(problem, small_graphs):
    g = small_graphs["grid6"]
    prob = build(problem, g)
    exact, _ = baselines.exact_lp(problem, g)

    # legacy shim path (sequential, batch_width=1) via the old signatures
    if problem == "match":
        legacy = maximize_packing(prob.P, prob.c, float(prob.lo), float(prob.hi), OPTS)
    elif problem in ("vcover", "dom-set"):
        legacy = minimize_covering(prob.C, prob.c, float(prob.lo), float(prob.hi), OPTS)
    else:
        def make_PC(D):
            from repro.core import ScaledRows

            return ScaledRows(scale=jnp.full((g.n,), 1.0 / D), inner=prob.P), prob.C

        legacy = densest_subgraph_search(make_PC, float(prob.lo), float(prob.hi), OPTS)

    sol = Solver(OPTS, batch_width=4).solve(prob)
    assert isinstance(sol, Solution)
    assert sol.found and legacy.found

    val_new = sol.bound if problem == "dense-sub" else sol.objective
    val_old = legacy.bound if problem == "dense-sub" else legacy.objective
    # both are (1+eps)-certified: each within 1.5 eps of exact, and hence
    # of each other within the combined band
    assert abs(val_new - exact) / max(abs(exact), 1e-12) <= 1.5 * EPS
    assert abs(val_old - exact) / max(abs(exact), 1e-12) <= 1.5 * EPS
    assert abs(val_new - val_old) / max(abs(exact), 1e-12) <= 3.0 * EPS


def test_solver_certificates(small_graphs):
    """The returned x must itself satisfy the (1+eps) feasibility claims."""
    g = small_graphs["rgg10"]
    sol = Solver(OPTS, batch_width=4).solve(build("match", g))
    x = sol.x
    loads = np.zeros(g.n)
    np.add.at(loads, g.u, x)
    np.add.at(loads, g.v, x)
    assert loads.max() <= 1.0 + 1e-6  # rescaled: strictly Px <= 1
    assert (x >= 0).all()


# ------------------------------------------------------ batched execution --
def test_solve_batch_matches_sequential(small_graphs):
    prob = build("match", small_graphs["grid6"])
    bounds = np.geomspace(float(prob.lo), float(prob.hi), 3)
    solver = Solver(OPTS)
    batch = solver.solve_batch(prob, bounds)
    # one vmapped XLA call: every result field carries the batch dim
    assert batch.status.shape == (3,)
    assert batch.x.shape == (3, prob.n_vars)
    for j, b in enumerate(bounds):
        res = solver.feasible(prob, float(b))
        assert int(res.status) == int(np.asarray(batch.status)[j])
        # same mathematical trajectory; XLA vectorization may round
        # differently, so certificates agree only to float tolerance
        assert abs(float(res.max_px) - float(np.asarray(batch.max_px)[j])) <= 5e-3
        assert abs(int(res.iters) - int(np.asarray(batch.iters)[j])) <= max(2, int(res.iters) // 20)


def test_solve_batch_speculative_search_uses_fanout(small_graphs):
    """batch_width>1 must evaluate >= 2 bounds per call and finish in
    fewer search rounds than the sequential driver."""
    prob = build("vcover", small_graphs["grid6"])
    seq = Solver(OPTS, batch_width=1).solve(prob)
    fan = Solver(OPTS, batch_width=4).solve(prob)
    assert fan.found and seq.found
    assert abs(fan.objective - seq.objective) <= 3.0 * EPS * seq.objective
    # fan-out probes more bounds total but that is the point: wall-clock
    # rounds (calls / width) shrink
    assert fan.feasibility_calls >= 2


def test_stacked_instances_batch():
    """vmap across independent graph instances (tree-stacked Problems)."""
    gs = [erdos(60, 150, seed=s) for s in (0, 1)]
    assert gs[0].m == gs[1].m  # generator pads/subsamples to exactly m
    probs = [build("match", g) for g in gs]
    stacked = stack_problems(probs)
    bounds = jnp.asarray([np.sqrt(float(p.lo) * float(p.hi)) for p in probs])
    solver = Solver(OPTS)
    batch = solver.solve_batch(stacked, bounds, batched_problem=True)
    assert batch.status.shape == (2,)
    for j, (p, b) in enumerate(zip(probs, bounds)):
        res = solver.feasible(p, float(b))
        assert int(res.status) == int(np.asarray(batch.status)[j])


# ----------------------------------------------------------------- trace --
def test_traced_solve_records_convergence(small_graphs):
    sol = Solver(OPTS).solve(build("match", small_graphs["star"]), trace=True)
    assert sol.found
    assert sol.trace is not None and len(sol.trace) == sol.feasibility_calls
    for t in sol.trace:
        assert {"bound", "max_violation", "alpha", "probes"} <= set(t)
    # the certifying solve drove violation under eps
    feas_traces = [t for t in sol.trace if len(t["max_violation"]) and t["max_violation"][-1] <= EPS + 1e-9]
    assert feas_traces, "no traced call reached the eps band"


# ------------------------------------------- feasibility-only + box rows --
def test_feasibility_problem_facade():
    g = Graph.from_edges(6, np.array([[0, i] for i in range(1, 6)]), "star6")
    lb = np.zeros(6)
    ub = np.full(6, 3.0)
    lb[0] = 2.0
    sol = Solver(OPTS).solve(generalized_matching_problem(g, lb, ub))
    assert sol.feasible
    assert np.isnan(sol.objective)  # feasibility problems have no objective
    # the x <= 1 box rows must hold up to the (1+eps) packing slack
    assert sol.x.max() <= 1.0 + EPS + 1e-6


def test_generalized_matching_box_rows_bind():
    """A single edge with lb = 1.5 is feasible WITHOUT the x <= 1 box
    (x = 1.5) but infeasible with it — the box rows must exist."""
    g = Graph.from_edges(2, np.array([[0, 1]]), "edge")
    lb = np.array([1.5, 0.0])
    ub = np.array([3.0, 3.0])
    P, C, c_mask = generalized_matching_lp(g, lb, ub)
    assert P.shape == (2 + 1, 1)  # two degree rows + one box row
    res = solve(P, C, OPTS, c_mask=c_mask)
    assert int(res.status) != Status.FEASIBLE


def test_generalized_matching_box_rows_materialize():
    g = Graph.from_edges(3, np.array([[0, 1], [1, 2]]), "path3")
    ub = np.array([2.0, 2.0, 2.0])
    P, _, _ = generalized_matching_lp(g, np.zeros(3), ub)
    dense = np.asarray(P.materialize())
    expect = np.vstack([
        np.array([[1.0, 0.0], [1.0, 1.0], [0.0, 1.0]]) / ub[:, None],
        np.eye(2),
    ])
    np.testing.assert_allclose(dense, expect)


# ------------------------------------------------------- legacy entry pts --
def test_legacy_problemlp_alias(small_graphs):
    from repro.graphs import ProblemLP

    prob = build("match", small_graphs["triangle"])
    assert isinstance(prob, ProblemLP)  # deprecated alias of Problem
    res = prob.solve(OPTS)  # ProblemLP.solve IS the new path
    assert res.found


def test_legacy_not_found_paths():
    """Shim preserves the not-found contract when even the easy bound fails."""
    # max <c,x> : x <= 1 (single var) cannot reach an objective of 10
    P = OnesRow(c=jnp.ones((1,)), inv_bound=jnp.asarray(1.0))
    res = maximize_packing(P, jnp.ones((1,)), 10.0, 20.0, OPTS)
    assert not res.found
    assert res.objective == 0.0
