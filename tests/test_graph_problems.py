"""Graph LP end-to-end: MWU vs exact (HiGHS) on every problem family.

This is the correctness core of the reproduction: the paper claims
(1+eps)-relative solutions with eps=0.1 across match/bmatch/vcover/
dom-set/dense-sub; we assert exactly that against exact LP values.
"""
import numpy as np
import pytest

from repro.core import MWUOptions, Status, solve
from repro.graphs import baselines, bipartite_ratings, build, generalized_matching_lp, kron, rgg
from repro.graphs.problems import bmatching_lp

EPS = 0.1
OPTS = MWUOptions(eps=EPS, step_rule="newton", max_iter=20000)


@pytest.mark.parametrize("problem", ["match", "vcover", "dom-set", "dense-sub"])
@pytest.mark.parametrize("gname", ["grid6", "rgg10", "kron8", "er", "star", "triangle"])
def test_mwu_within_eps_of_exact(problem, gname, small_graphs):
    g = small_graphs[gname]
    lp = build(problem, g)
    exact, _ = baselines.exact_lp(problem, g)
    res = lp.solve(OPTS)
    assert res.found, f"{problem}/{gname}: no solution found"
    val = res.bound if problem == "dense-sub" else res.objective
    rel = abs(val - exact) / max(abs(exact), 1e-12)
    # binary search on the bound compounds with the solver's eps; the
    # paper's own acceptance is relative error <= eps (§6.2), with one
    # observed excursion to 0.104. We allow 1.5 eps of slack.
    assert rel <= 1.5 * EPS, f"{problem}/{gname}: exact={exact} mwu={val} rel={rel}"


def test_bmatch_bipartite():
    g = bipartite_ratings(60, 40, avg_ratings=12.0, seed=0)
    lp = bmatching_lp(g)
    exact = baselines.hopcroft_karp_bmatch(g)
    res = lp.solve(OPTS)
    assert res.found
    # bipartite matching LP is integral: exact == LP optimum
    assert res.objective >= (1 - 1.5 * EPS) * exact
    assert res.objective <= exact * (1 + 1e-6) + 1e-6


def test_matching_solution_is_feasible(small_graphs):
    g = small_graphs["rgg10"]
    lp = build("match", g)
    res = lp.solve(OPTS)
    x = res.x
    # Mx <= 1 (after the driver's rescale)
    loads = np.zeros(g.n)
    np.add.at(loads, g.u, x)
    np.add.at(loads, g.v, x)
    assert loads.max() <= 1.0 + 1e-6
    assert (x >= 0).all()


def test_vcover_duality_sandwich(small_graphs):
    """LP vcover == LP matching (strong duality): both MWU answers must
    sandwich the common optimum within eps bands."""
    g = small_graphs["grid6"]
    mv = build("match", g).solve(OPTS).objective
    vc = build("vcover", g).solve(OPTS).objective
    # mv <= OPT <= vc/(1-ish); allow combined 2*eps slack
    assert mv <= vc * (1 + 2 * EPS)
    assert vc <= mv * (1 + 2 * EPS) / (1 - EPS)


def test_generalized_matching_feasibility():
    g = bipartite_ratings(50, 30, avg_ratings=15.0, seed=1)
    deg = g.degrees()
    s = g.bipartite_split
    lb = np.zeros(g.n)
    ub = np.ones(g.n)
    # users: between 1 and 5 matches; items: up to 8 (degree permitting)
    lb[:s] = np.minimum(1, deg[:s])
    ub[:s] = 5
    ub[s:] = 8
    P, C, c_mask = generalized_matching_lp(g, lb, ub)
    res = solve(P, C, MWUOptions(eps=0.1, step_rule="newton", max_iter=20000), c_mask=c_mask)
    assert int(res.status) == Status.FEASIBLE
    x = np.asarray(res.x)
    loads = np.zeros(g.n)
    np.add.at(loads, g.u, x)
    np.add.at(loads, g.v, x)
    assert (loads <= ub * 1.1 + 1e-9).all()
    assert (loads >= lb * (1 - 1e-9) - 1e-9)[lb > 0].all()


def test_generators_shapes():
    g = rgg(9, seed=0)
    assert g.n == 512 and g.m > 512  # ~15x edges expected
    g.validate()
    k = kron(8, seed=0, edgefactor=8)
    assert k.n == 256
    k.validate()
    b = bipartite_ratings(40, 20, seed=0)
    b.validate()
    assert b.bipartite_split == 40


def test_baseline_sanity(small_graphs):
    g = small_graphs["grid6"]
    gm = baselines.greedy_maximal_matching(g)
    assert 9 <= gm <= 18  # maximal matching of 6x6 grid
    rho, size = baselines.charikar_peel(g)
    assert rho >= 60 / 36 - 1e-9  # full graph density reachable
    ds = baselines.greedy_dominating_set(g)
    assert 4 <= ds <= 18
    vc = baselines.matching_vertex_cover(g)
    assert 18 <= vc <= 36
