"""repro.dist — mesh-sharded solver layer tests.

Two tiers, mirroring tests/test_distributed.py's isolation rule:

* in-process: ``MeshPlan(1, 1)`` runs on the session's single device and
  must be BIT-identical to the plain ``Solver`` (the identity-plan
  contract), plus host-side plumbing (plan validation, mode selection,
  compat kwargs).
* subprocess: each multi-device test spawns a fresh python with
  ``--xla_force_host_platform_device_count`` so the main session keeps
  its single device; pod-sharded runs are compared to the single-device
  oracle on solution *quality* (status + certificates) — psum
  re-association forks the line-search trajectory, so pointwise x
  equality is not expected (nor required by the paper's MPI runs).
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(code: str, devices: int = 8, timeout=900, retries: int = 2):
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        f"import sys; sys.path.insert(0, {SRC!r})\n" + textwrap.dedent(code)
    )
    for attempt in range(retries + 1):
        res = subprocess.run(
            [sys.executable, "-c", prog], capture_output=True, text=True, timeout=timeout
        )
        if res.returncode == 0:
            return res.stdout
        # XLA-CPU collectives busy-wait; retry spurious rendezvous timeouts.
        if "rendezvous" not in res.stderr.lower() or attempt == retries:
            assert res.returncode == 0, f"stderr:\n{res.stderr[-3000:]}"
    return res.stdout


# ---------------------------------------------------------- in-process ----
def _families(g):
    from repro.graphs.problems import (
        densest_subgraph_lp,
        domset_lp,
        matching_lp,
        vcover_lp,
    )

    return [
        (matching_lp(g), [2.0, 5.0, 9.0]),
        (vcover_lp(g), [10.0, 25.0]),
        (domset_lp(g), [5.0, 15.0]),
        (densest_subgraph_lp(g), [2.0, 4.0]),
    ]


def test_identity_plan_bitparity_solve_batch():
    """MeshPlan(1,1) results are bit-identical to Solver.solve_batch."""
    from repro.api import Solver
    from repro.dist import DistSolver, MeshPlan
    from repro.graphs.generators import erdos

    g = erdos(40, 120, seed=0)
    dist = DistSolver(plan=MeshPlan(1, 1))
    for prob, bounds in _families(g):
        ref = Solver().solve_batch(prob, bounds)
        got = dist.solve_batch(prob, bounds)
        for f in ref._fields:
            a, b = np.asarray(getattr(ref, f)), np.asarray(getattr(got, f))
            assert np.array_equal(a, b), f"{prob.name}.{f} not bit-identical"


def test_identity_plan_bitparity_feasibility_problem():
    """bound_mode='none' (gen-match) also bit-matches on the identity plan."""
    from repro.api import Solver
    from repro.dist import DistSolver, MeshPlan
    from repro.graphs.generators import erdos
    from repro.graphs.problems import generalized_matching_problem

    g = erdos(30, 80, seed=2)
    lb = np.zeros(g.n)
    ub = np.full(g.n, 2.0)
    prob = generalized_matching_problem(g, lb, ub)
    ref = Solver().solve_batch(prob, [1.0])
    got = DistSolver(plan=MeshPlan(1, 1)).solve_batch(prob, [1.0])
    for f in ref._fields:
        assert np.array_equal(np.asarray(getattr(ref, f)), np.asarray(getattr(got, f))), f


def test_identity_plan_solve_parity():
    """The inherited bound-search driver returns the identical Solution."""
    from repro.api import Solver
    from repro.dist import DistSolver, MeshPlan
    from repro.graphs.generators import erdos
    from repro.graphs.problems import matching_lp

    prob = matching_lp(erdos(40, 120, seed=0))
    ref = Solver().solve(prob)
    got = DistSolver(plan=MeshPlan(1, 1)).solve(prob)
    assert got.status == ref.status
    assert got.objective == ref.objective
    assert got.bound == ref.bound
    assert got.feasibility_calls == ref.feasibility_calls
    np.testing.assert_array_equal(got.x, ref.x)


def test_mesh_plan_validation():
    from repro.dist import MeshPlan

    with pytest.raises(ValueError, match=">= 1"):
        MeshPlan(pod=0)
    with pytest.raises(ValueError, match=">= 1"):
        MeshPlan(data=-1)
    # more devices than the host exposes -> actionable error at build()
    with pytest.raises(ValueError, match="xla_force_host_platform_device_count"):
        MeshPlan(pod=64, data=64).build()
    # identity plan builds and is cached
    plan = MeshPlan()
    assert plan.build() is plan.build()
    assert plan.n_devices == 1 and plan.axes == ("pod", "data")


def test_pod_mode_selection():
    from repro.dist import pod_mode
    from repro.graphs.generators import erdos
    from repro.graphs.problems import (
        densest_subgraph_lp,
        domset_lp,
        matching_lp,
        vcover_lp,
    )

    g = erdos(20, 40, seed=0)
    assert pod_mode(matching_lp(g)) == "edge_slab"  # the paper's scheme
    assert pod_mode(vcover_lp(g)) == "column"
    assert pod_mode(domset_lp(g)) == "column"
    assert pod_mode(densest_subgraph_lp(g)) == "column"


def test_slab_pad_problem():
    from repro.dist import slab_pad_problem
    from repro.graphs.generators import erdos
    from repro.graphs.problems import matching_lp

    prob = matching_lp(erdos(30, 77, seed=1))  # 77 % 4 != 0
    padded, ncols = slab_pad_problem(prob, 4)
    assert ncols == 77
    E_pad = int(padded.P.u.shape[-1])
    assert E_pad % 4 == 0 and E_pad >= 77
    mask = np.asarray(padded.P.edge_mask)
    assert mask[:77].all() and not mask[77:].any()
    assert np.asarray(padded.c)[77:].sum() == 0
    # pod=1 is the identity (no padding, same object)
    same, n = slab_pad_problem(prob, 1)
    assert n == 77 and same is prob


def test_compat_shard_map_kwargs():
    """Both check_vma and the legacy check_rep spelling are accepted."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.dist import MeshPlan
    from repro.utils import compat

    mesh = MeshPlan(1, 1).build()

    def body(x):
        return x * 2

    for kw in ({"check_vma": False}, {"check_rep": False}, {}):
        f = compat.shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(), **kw)
        out = jax.jit(f)(jnp.arange(4.0))
        np.testing.assert_array_equal(np.asarray(out), np.arange(4.0) * 2)


# ---------------------------------------------------------- subprocess ----
def test_multi_device_parity():
    """8 virtual devices: edge-slab, column and combined pod x data plans
    all match the single-device oracle on status + certificates."""
    out = run_sub(
        """
        import json
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.graphs.generators import erdos
        from repro.graphs.problems import matching_lp, vcover_lp, domset_lp
        from repro.api import Solver
        from repro.dist import DistSolver, MeshPlan

        g = erdos(60, 201, seed=1)  # E=201: not divisible by 8 -> slab padding
        cases = [
            ("match-pod8", matching_lp(g), [3.0, 7.0, 12.0, 20.0], MeshPlan(pod=8, data=1)),
            ("match-pod2data4", matching_lp(g), [3.0, 7.0, 12.0, 20.0], MeshPlan(pod=2, data=4)),
            ("match-data8", matching_lp(g), [3.0, 7.0, 12.0, 20.0], MeshPlan(pod=1, data=8)),
            ("vcover-pod8", vcover_lp(g), [15.0, 40.0], MeshPlan(pod=8, data=1)),
            ("domset-pod4data2", domset_lp(g), [6.0, 18.0], MeshPlan(pod=4, data=2)),
        ]
        rows = {}
        for name, prob, bounds, plan in cases:
            ref = Solver().solve_batch(prob, bounds)
            dst = DistSolver(plan=plan).solve_batch(prob, bounds)
            # recompute certificates from the returned x: catches any
            # slab-reassembly/ordering bug independent of trajectory noise
            recheck = []
            for j, b in enumerate(bounds):
                P, C, pm, cm = prob.instantiate(float(b))
                x = jnp.asarray(np.asarray(dst.x)[j])
                px = np.asarray(P.matvec(x)); cx = np.asarray(C.matvec(x))
                if pm is not None: px = px[np.asarray(pm)]
                if cm is not None: cx = cx[np.asarray(cm)]
                recheck.append([float(px.max()), float(cx.min())])
            rows[name] = {
                "ref_status": np.asarray(ref.status).tolist(),
                "dst_status": np.asarray(dst.status).tolist(),
                "ref_max_px": np.asarray(ref.max_px).tolist(),
                "dst_max_px": np.asarray(dst.max_px).tolist(),
                "ref_min_cx": np.asarray(ref.min_cx).tolist(),
                "dst_min_cx": np.asarray(dst.min_cx).tolist(),
                "recheck": recheck,
            }
        print(json.dumps(rows))
        """,
        devices=8,
    )
    rows = json.loads(out.strip().splitlines()[-1])
    for name, d in rows.items():
        assert d["dst_status"] == d["ref_status"], name
        np.testing.assert_allclose(
            d["dst_max_px"], d["ref_max_px"], rtol=5e-3, atol=5e-3, err_msg=name
        )
        np.testing.assert_allclose(
            d["dst_min_cx"], d["ref_min_cx"], rtol=5e-3, atol=5e-3, err_msg=name
        )
        got = np.asarray(d["recheck"])
        np.testing.assert_allclose(got[:, 0], d["dst_max_px"], rtol=1e-4, atol=1e-5, err_msg=name)
        np.testing.assert_allclose(got[:, 1], d["dst_min_cx"], rtol=1e-4, atol=1e-5, err_msg=name)
    # the pure data fan-out runs the same per-lane program (unbatched on
    # each device vs vmapped in the oracle) — certificates must agree to
    # f32 fusion round-off, an order tighter than pod trajectory noise
    d = rows["match-data8"]
    np.testing.assert_allclose(d["dst_max_px"], d["ref_max_px"], rtol=1e-4)


def test_lpserve_mesh_sharded_lanes():
    """LPEngine on a (2,2) plan: same answers as the sequential engine on
    mixed-size (bucket-padded, masked) graphs + per-device mesh stats."""
    out = run_sub(
        """
        import json
        import numpy as np
        from repro.graphs.generators import erdos
        from repro.graphs.problems import matching_lp, vcover_lp
        from repro.dist import MeshPlan
        from repro.lpserve import LPEngine, LPServeConfig

        probs = [matching_lp(erdos(30 + 10 * i, 80 + 25 * i, seed=i), name="match")
                 for i in range(5)]
        probs += [vcover_lp(erdos(40, 110, seed=9))]

        ref = LPEngine(LPServeConfig(lanes=4)).solve_many(probs)
        eng = LPEngine(LPServeConfig(lanes=4, mesh=MeshPlan(pod=2, data=2)))
        sols = eng.solve_many(probs)
        st = eng.stats()
        print(json.dumps({
            "ref": [[s.feasible, s.objective] for s in ref],
            "dst": [[s.feasible, s.objective] for s in sols],
            "mesh": st["mesh"],
            "completed": st["completed"],
        }))
        """,
        devices=4,
    )
    d = json.loads(out.strip().splitlines()[-1])
    assert d["completed"] == 6
    for (rf, ro), (df, do) in zip(d["ref"], d["dst"]):
        assert rf == df
        if rf:
            np.testing.assert_allclose(do, ro, rtol=0.1)
    mesh = d["mesh"]
    assert mesh["devices"] == 4 and mesh["pod"] == 2 and mesh["data"] == 2
    assert mesh["lanes_per_device"] == 2
    assert mesh["dist_launches"] > 0
    assert mesh["psum_rounds"] > 0  # pod sharding actually communicated


def test_pallas_pack_active_under_shard_map():
    """The no-vmap fast path keeps the fused Pallas kernels (interpret
    mode on CPU) on the hot path inside shard_map — the custom_vmap XLA
    fallback only applies to vmapped lanes."""
    out = run_sub(
        """
        import json
        import numpy as np
        from repro.graphs.generators import erdos
        from repro.graphs.problems import matching_lp
        from repro.core.mwu import MWUOptions
        from repro.kernels import dispatch
        from repro.api import Solver
        from repro.dist import DistSolver, MeshPlan

        prob = matching_lp(erdos(60, 201, seed=1))
        solver = DistSolver(MWUOptions(kernel_backend="pallas"),
                            plan=MeshPlan(pod=2, data=1))
        before = dispatch.stats().get("gather", {}).get("pallas", 0)
        res = solver.feasible(prob, 7.0)
        after = dispatch.stats().get("gather", {}).get("pallas", 0)
        ref = Solver().feasible(prob, 7.0)
        print(json.dumps({
            "pallas_gather_delta": after - before,
            "status": int(res.status), "ref_status": int(ref.status),
            "max_px": float(res.max_px), "ref_max_px": float(ref.max_px),
        }))
        """,
        devices=2,
    )
    d = json.loads(out.strip().splitlines()[-1])
    assert d["pallas_gather_delta"] > 0, "Pallas pack fell back to XLA under shard_map"
    assert d["status"] == d["ref_status"]
    np.testing.assert_allclose(d["max_px"], d["ref_max_px"], rtol=5e-3, atol=5e-3)
