"""The differential-jaxpr and cost-model passes (ISSUE 10): parity
proofs hold on the real tree, fail on perturbations; cost cells gate
against the committed baseline; capture failures are named findings."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.api.solver import Solver
from repro.core.mwu import MWUOptions
from repro.tracecheck import costmodel
from repro.tracecheck.capture import _batch_bounds, _mid_bound, build_problem
from repro.tracecheck.cli import CAPTURE_RULE, run_matrix
from repro.tracecheck.diff import (
    BACKEND_PARITY_RULE,
    DIST_PARITY_RULE,
    canonical_tokens,
    check_backend_parity,
    check_dist_identity,
)
from repro.tracecheck.matrix import Case
from repro.tracecheck.report import prune_baseline
from repro.tracecheck.rules import Finding


@pytest.fixture(scope="module")
def problem():
    return build_problem("match")


@pytest.fixture(scope="module")
def xla_jaxpr(problem):
    return Solver(MWUOptions(kernel_backend="xla")).jaxpr_feasible(problem, _mid_bound(problem))


# ------------------------------------------------------ backend parity --
def test_backend_parity_clean_on_real_tree(problem, xla_jaxpr):
    jp = Solver(MWUOptions(kernel_backend="pallas")).jaxpr_feasible(problem, _mid_bound(problem))
    assert check_backend_parity(xla_jaxpr, jp, "parity:match:backend") == []


def test_backend_parity_fails_on_structural_perturbation(problem, xla_jaxpr):
    """The traced-hook variant adds an io_callback inside the while body —
    a structural divergence with no dispatch primitive to excuse it."""
    jt = Solver(MWUOptions(kernel_backend="xla")).jaxpr_feasible(
        problem, _mid_bound(problem), trace=True
    )
    findings = check_backend_parity(xla_jaxpr, jt, "perturbed")
    assert len(findings) == 1
    f = findings[0]
    assert f.rule == BACKEND_PARITY_RULE and f.severity == "error"
    assert "io_callback" in f.message


# -------------------------------------------------------- dist identity --
def test_dist_identity_clean_on_real_tree(problem):
    from repro.dist.mesh import MeshPlan
    from repro.dist.solver import DistSolver

    bounds = _batch_bounds(problem, 2)
    js = Solver(MWUOptions()).jaxpr_batch(problem, bounds)
    jd = DistSolver(MWUOptions(), plan=MeshPlan(pod=1, data=1)).jaxpr_batch(problem, bounds)
    # the shard_map/pjit shells unwrap to token-for-token equality
    assert canonical_tokens(js) == canonical_tokens(jd)
    assert check_dist_identity(js, jd, "parity:match:dist") == []


def test_dist_identity_fails_on_perturbation(problem):
    """Any op-level drift (here: a different smoothing accuracy constant)
    must produce a failing parity finding."""
    bounds = _batch_bounds(problem, 2)
    js = Solver(MWUOptions()).jaxpr_batch(problem, bounds)
    jd = Solver(MWUOptions(eps=0.05)).jaxpr_batch(problem, bounds)
    findings = check_dist_identity(js, jd, "perturbed")
    assert len(findings) == 1
    assert findings[0].rule == DIST_PARITY_RULE
    assert findings[0].detail["n_regions"] >= 1


# ------------------------------------------------------------ costmodel --
_COST_HLO = """\
HloModule synth

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %it = s32[] get-tuple-element((s32[], f32[8,8]) %p), index=0
  %k = s32[] constant(40)
  ROOT %lt = pred[] compare(s32[] %it, s32[] %k), direction=LT
}

%body (q: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %q = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8,8]) %q), index=0
  %a = f32[8,8] get-tuple-element((s32[], f32[8,8]) %q), index=1
  %one = s32[] constant(1)
  %i1 = s32[] add(s32[] %i, s32[] %one)
  %d = f32[8,8] dot(f32[8,8] %a, f32[8,8] %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(s32[] %i1, f32[8,8] %d)
}

ENTRY %main (x: f32[8,8]) -> (s32[], f32[8,8]) {
  %x = f32[8,8] parameter(0)
  %z = s32[] constant(0)
  %c0 = (s32[], f32[8,8]) tuple(s32[] %z, f32[8,8] %x)
  ROOT %w = (s32[], f32[8,8]) while((s32[], f32[8,8]) %c0), condition=%cond, body=%body
}
"""


def test_iteration_cost_counts_body_once():
    cost = costmodel.iteration_cost(_COST_HLO)
    # one 8x8x8 dot per iteration: NOT multiplied by the trip bound 40
    assert cost["flops"] == 2 * 8 * 8 * 8
    assert cost["trip_bound"] == 40
    assert cost["n_collectives"] == 0
    assert cost["roofline"]["bottleneck"] in ("compute", "memory", "collective")


def test_iteration_cost_none_without_loop():
    assert costmodel.iteration_cost("HloModule empty\n\nENTRY %main (x: f32[4]) -> f32[4] {\n  ROOT %x = f32[4] parameter(0)\n}\n") is None


def test_cost_regression_2x_flops_fails():
    cell = costmodel.iteration_cost(_COST_HLO)
    baseline = {"synth": {m: cell[m] / 2 if m == "flops" else cell[m]
                          for m in costmodel.DEFAULT_TOLERANCES}}
    findings = costmodel.check_costs({"synth": cell}, baseline)
    assert len(findings) == 1
    f = findings[0]
    assert f.rule == costmodel.COST_RULE and f.severity == "error"
    assert f.fingerprint == "cost-regression::synth::flops"
    assert f.detail["current"] == 2 * f.detail["baseline"]


def test_cost_within_tolerance_passes():
    cell = costmodel.iteration_cost(_COST_HLO)
    base = {m: cell[m] for m in costmodel.DEFAULT_TOLERANCES}
    assert costmodel.check_costs({"synth": cell}, {"synth": base}) == []
    # shrinking never fails (ratcheting down is a baseline regen, not a gate)
    grown = {m: v * 10 for m, v in base.items()}
    assert costmodel.check_costs({"synth": cell}, {"synth": grown}) == []


def test_extra_collective_fails_at_zero_tolerance():
    cell = dict(costmodel.iteration_cost(_COST_HLO))
    cell["n_collectives"] = 1
    base = {m: 0 if m == "n_collectives" else cell[m] for m in costmodel.DEFAULT_TOLERANCES}
    findings = costmodel.check_costs({"synth": cell}, {"synth": base})
    assert [f.key for f in findings] == ["n_collectives"]


def test_missing_baseline_warns_not_errors():
    cell = costmodel.iteration_cost(_COST_HLO)
    findings = costmodel.check_costs({"new-cell": cell}, {})
    assert len(findings) == 1
    assert findings[0].severity == "warning"
    assert findings[0].key == "missing-baseline"


def test_cost_baseline_roundtrip(tmp_path):
    cell = costmodel.iteration_cost(_COST_HLO)
    path = str(tmp_path / "cost.json")
    costmodel.write_cost_baseline({"synth": cell}, path)
    loaded = costmodel.load_cost_baseline(path)
    assert set(loaded) == {"synth"}
    assert loaded["synth"]["flops"] == cell["flops"]
    assert costmodel.check_costs({"synth": cell}, loaded) == []


def test_shipped_cost_baseline_covers_solve_cells():
    """The committed baseline must gate every family x backend solve cell."""
    cells = costmodel.load_cost_baseline()
    for fam in ("match", "vcover", "dense-sub", "gen-match"):
        for backend in ("xla", "pallas"):
            assert f"solve:{fam}:{backend}" in cells


def test_compiled_solver_cell_produces_cost(problem):
    hlo = (
        Solver(MWUOptions())
        .lower_feasible(problem, _mid_bound(problem))
        .compile()
        .as_text()
    )
    cost = costmodel.iteration_cost(hlo)
    assert cost is not None and cost["flops"] > 0 and cost["hbm_bytes"] > 0


# ------------------------------------------- capture-error (satellite) --
def test_capture_failure_is_a_named_finding_not_a_crash():
    """One broken lowering hook must not abort the sweep: the cell becomes
    an error finding naming family/backend, later cases still lint."""
    report = run_matrix(
        cases=[Case("bogus", "match", "xla"), Case("kernel", op="gather")],
        verbose=False,
    )
    assert not report["ok"]
    errs = [f for f in report["findings"] if f["rule"] == CAPTURE_RULE]
    assert len(errs) == 1
    assert errs[0]["artifact"] == "bogus:match:xla"
    assert "family `match`" in errs[0]["message"]
    assert "backend `xla`" in errs[0]["message"]
    # the sweep continued: the kernel artifact was still captured + linted
    assert "kernel:gather" in report["artifacts"]


# ------------------------------------------- prune-baseline (satellite) --
def test_prune_baseline_drops_stale_keeps_live(tmp_path):
    live = Finding(rule="kernel-path", severity="error", artifact="a", message="m", key="missing")
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"allow": [live.fingerprint, "dead-rule::gone::x"]}))
    removed = prune_baseline([live], str(path))
    assert removed == ["dead-rule::gone::x"]
    assert json.loads(path.read_text()) == {"allow": [live.fingerprint]}
    # idempotent: nothing left to prune
    assert prune_baseline([live], str(path)) == []
