"""utils.deprecation.warn_once: once per process per key, thread-safe."""
import threading
import warnings

from repro.utils import deprecation
from repro.utils.deprecation import warn_once


def _fresh(monkeypatch):
    monkeypatch.setattr(deprecation, "_WARNED", set())


def test_warns_once_per_key(monkeypatch):
    _fresh(monkeypatch)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        warn_once("k1", "shim k1 is deprecated")
        warn_once("k1", "shim k1 is deprecated")
        warn_once("k1", "different text, same key")
    assert len(rec) == 1
    assert issubclass(rec[0].category, DeprecationWarning)
    assert "k1" in str(rec[0].message)


def test_distinct_keys_each_warn(monkeypatch):
    _fresh(monkeypatch)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        warn_once("a", "A")
        warn_once("b", "B")
        warn_once("a", "A again")
    assert [str(r.message) for r in rec] == ["A", "B"]


def test_thread_safe_reentry(monkeypatch):
    """N threads racing on one fresh key must produce exactly one warning.

    The recorder is installed once in the main thread (catch_warnings
    itself mutates global state and is not safe to nest concurrently);
    a barrier lines all threads up on the same first-call race.
    """
    _fresh(monkeypatch)
    n = 16
    barrier = threading.Barrier(n)

    def hit():
        barrier.wait()
        warn_once("raced", "raced shim")

    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        threads = [threading.Thread(target=hit) for _ in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert len(rec) == 1
    assert "raced" in str(rec[0].message)
