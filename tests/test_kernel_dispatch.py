"""The kernels.dispatch backend-selection layer, end to end.

Four layers of coverage:

1. Policy resolution — explicit request vs env var vs platform default,
   and the per-op gates (f64-on-TPU, VMEM vertex limit, masks).
2. The custom_vmap pallas wrappers — unbatched calls match the oracles;
   vmapped calls take the batch rule and match vmapped oracles.
3. Operator / smoothing / stepsize wiring — pallas-policy results match
   the default XLA policy on the same inputs, including weighted,
   masked, and padded-edge-slot operators (which must fall back).
4. End-to-end ``solve(kernel_backend="pallas")`` vs ``"xla"`` on all
   four problem families, with dispatch stats proving the kernel path
   was genuinely active (not silently falling back).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import operators as ops
from repro.core.smoothing import smax_and_weights, smin_and_weights
from repro.core.stepsize import make_probe_fn
from repro.graphs import build, grid2d
from repro.kernels import dispatch as kd

PALLAS = kd.KernelPolicy("pallas", interpret=True)


@pytest.fixture(autouse=True)
def _clean_stats():
    kd.reset_stats()
    yield
    kd.reset_stats()


# -- 1. policy resolution ---------------------------------------------------
def test_resolve_explicit_requests(monkeypatch):
    monkeypatch.delenv(kd.ENV_VAR, raising=False)
    assert kd.resolve("xla") == kd.XLA_POLICY
    pol = kd.resolve("pallas")
    assert pol.backend == "pallas"
    # interpret mode everywhere except a real TPU
    assert pol.interpret == (jax.default_backend() != "tpu")


def test_resolve_auto_follows_platform(monkeypatch):
    monkeypatch.delenv(kd.ENV_VAR, raising=False)
    pol = kd.resolve("auto")
    if jax.default_backend() == "tpu":
        assert pol == kd.KernelPolicy("pallas", interpret=False)
    else:
        assert pol == kd.XLA_POLICY
    assert kd.resolve(None) == pol


def test_resolve_env_var_overrides_auto_but_not_explicit(monkeypatch):
    monkeypatch.setenv(kd.ENV_VAR, "pallas")
    assert kd.resolve("auto").backend == "pallas"
    assert kd.resolve("xla") == kd.XLA_POLICY
    monkeypatch.setenv(kd.ENV_VAR, "xla")
    assert kd.resolve("auto") == kd.XLA_POLICY


def test_resolve_rejects_unknown_backend(monkeypatch):
    with pytest.raises(ValueError, match="kernel backend"):
        kd.resolve("mosaic")
    monkeypatch.setenv(kd.ENV_VAR, "bogus")
    with pytest.raises(ValueError, match="kernel backend"):
        kd.resolve("auto")


def test_env_var_is_reread_per_resolve(monkeypatch):
    """Satellite fix: backend choice must never come from a stale cache."""
    monkeypatch.setenv(kd.ENV_VAR, "pallas")
    first = kd.resolve("auto")
    monkeypatch.setenv(kd.ENV_VAR, "xla")
    second = kd.resolve("auto")
    assert first.backend == "pallas" and second.backend == "xla"


def test_gate_default_policy_is_xla():
    x = jnp.ones(8)
    assert kd.active_policy() == kd.XLA_POLICY
    assert kd.choose("softmax", x) == "xla"
    with kd.use_policy(PALLAS):
        assert kd.choose("softmax", x) == "pallas"
    assert kd.choose("softmax", x) == "xla"  # scope restored
    s = kd.stats()
    assert s["softmax"] == {"pallas": 1, "xla": 2}


def test_gate_f64_requires_interpret():
    x64 = jnp.ones(8, jnp.float64)
    x32 = jnp.ones(8, jnp.float32)
    with kd.use_policy(kd.KernelPolicy("pallas", interpret=False)):
        assert kd.choose("softmax", x64) == "xla"  # no f64 VPU on real TPU
        assert kd.choose("softmax", x32) == "pallas"
    with kd.use_policy(PALLAS):
        assert kd.choose("softmax", x64) == "pallas"  # interpret keeps f64


def test_gate_gather_vmem_limit():
    assert kd.vmem_vertex_limit(jnp.float32) == kd.VMEM_VERTEX_LIMIT
    assert kd.vmem_vertex_limit(jnp.float64) == kd.VMEM_VERTEX_LIMIT // 2
    small = jax.ShapeDtypeStruct((16,), jnp.float32)
    big = jax.ShapeDtypeStruct((kd.VMEM_VERTEX_LIMIT + 1,), jnp.float32)
    with kd.use_policy(PALLAS):
        assert kd.choose("gather", small) == "pallas"
        assert kd.choose("gather", big) == "xla"
        # non-gather ops stream in tiles and have no vertex cap
        assert kd.choose("axpy", big) == "pallas"


# -- 2. the custom_vmap wrappers -------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_wrappers_match_oracles(dtype):
    rng = np.random.default_rng(0)
    n = 257
    y = jnp.asarray(rng.random(n), dtype)
    dy = jnp.asarray(rng.random(n) * 1e-2, dtype)
    u = jnp.asarray(rng.integers(0, n, 400), jnp.int32)
    v = jnp.asarray(rng.integers(0, n, 400), jnp.int32)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    with kd.use_policy(PALLAS):
        g = kd.gather_pallas(u, v, y)
        lse, w = kd.softmax_pallas(y, 50.0, sign=-1.0)
        pl, ps, pm = kd.probe_pallas(y, dy, 2.0, 50.0, sign=1.0)
        ax, mn, mx = kd.axpy_pallas(y, dy, 2.0)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(y[u] + y[v]))
    a = -50.0 * np.asarray(y, np.float64)
    np.testing.assert_allclose(float(lse), np.log(np.exp(a - a.max()).sum()) + a.max(), rtol=tol)
    np.testing.assert_allclose(float(w.sum()), 1.0, rtol=tol)
    yv = np.asarray(y, np.float64) + 2.0 * np.asarray(dy, np.float64)
    e = np.exp(50.0 * yv - (50.0 * yv).max())
    np.testing.assert_allclose(float(pl), np.log(e.sum()) + (50.0 * yv).max(), rtol=tol)
    np.testing.assert_allclose(float(ps), (e * np.asarray(dy, np.float64)).sum() / e.sum(), rtol=tol)
    np.testing.assert_allclose(float(pm), yv.min(), rtol=tol)
    np.testing.assert_allclose(np.asarray(ax), yv, rtol=tol)
    assert float(mn) == pytest.approx(yv.min(), rel=tol)
    assert float(mx) == pytest.approx(yv.max(), rel=tol)


def test_wrappers_under_vmap_use_batch_rule():
    """vmapped lanes must not hit pallas_call; they take the XLA rule."""
    rng = np.random.default_rng(1)
    B, n, E = 3, 64, 100
    ys = jnp.asarray(rng.random((B, n)))
    dys = jnp.asarray(rng.random((B, n)) * 1e-2)
    u = jnp.asarray(rng.integers(0, n, E), jnp.int32)
    v = jnp.asarray(rng.integers(0, n, E), jnp.int32)
    alphas = jnp.asarray(rng.random(B))
    with kd.use_policy(PALLAS):
        # unbatched index args, batched vector arg
        g = jax.vmap(lambda w: kd.gather_pallas(u, v, w))(ys)
        lse, w = jax.vmap(lambda x: kd.softmax_pallas(x, 30.0, sign=1.0))(ys)
        pr = jax.vmap(lambda y, dy, a: kd.probe_pallas(y, dy, a, 30.0, sign=-1.0))(
            ys, dys, alphas
        )
        ax = jax.vmap(lambda y, dy, a: kd.axpy_pallas(y, dy, a))(ys, dys, alphas)
    assert g.shape == (B, E) and lse.shape == (B,) and w.shape == (B, n)
    assert pr[0].shape == (B,) and ax[0].shape == (B, n)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(ys[:, u] + ys[:, v]))
    for b in range(B):
        a = 30.0 * np.asarray(ys[b], np.float64)
        np.testing.assert_allclose(
            float(lse[b]), np.log(np.exp(a - a.max()).sum()) + a.max(), rtol=1e-12
        )
        np.testing.assert_allclose(
            np.asarray(ax[0][b]), np.asarray(ys[b] + alphas[b] * dys[b]), rtol=1e-12
        )


# -- 3. operator / smoothing / stepsize wiring -----------------------------
def _incidence(E=300, n=97, seed=2, weights=False, mask=False):
    rng = np.random.default_rng(seed)
    kw = {}
    if weights:
        kw["weights"] = jnp.asarray(rng.random(E) + 0.5)
    if mask:
        kw["edge_mask"] = jnp.asarray(rng.random(E) > 0.25)  # padded slots off
    return ops.Incidence(
        u=jnp.asarray(rng.integers(0, n, E), jnp.int32),
        v=jnp.asarray(rng.integers(0, n, E), jnp.int32),
        n_vertices=n,
        **kw,
    )


@pytest.mark.parametrize("weights", [False, True])
@pytest.mark.parametrize("mask", [False, True])
def test_incidence_rmatvec_parity(weights, mask):
    M = _incidence(weights=weights, mask=mask)
    y = jnp.asarray(np.random.default_rng(3).random(M.n_vertices))
    ref = M.rmatvec(y)
    assert kd.stats().get("gather", {}).get("pallas", 0) == 0
    with kd.use_policy(PALLAS):
        got = M.rmatvec(y)
    assert kd.stats()["gather"]["pallas"] == 1
    # same gather, same weight/mask multiply: bit-identical
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("mask", [False, True])
def test_vertex_edge_pair_rmatvec_parity(mask):
    rng = np.random.default_rng(4)
    E, n = 200, 63
    O = ops.VertexEdgePair(
        u=jnp.asarray(rng.integers(0, n, E), jnp.int32),
        v=jnp.asarray(rng.integers(0, n, E), jnp.int32),
        n_vertices=n,
        edge_mask=jnp.asarray(rng.random(E) > 0.3) if mask else None,
    )
    y = jnp.asarray(rng.random(n))
    ref = O.rmatvec(y)
    with kd.use_policy(PALLAS):
        got = O.rmatvec(y)
    # interleaved pair-gather: 0.5 * (y[i] + y[i]) is exact
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_transposed_incidence_matvec_parity():
    """Vertex cover's C = M^T: matvec routes through Incidence.rmatvec."""
    M = _incidence()
    y = jnp.asarray(np.random.default_rng(5).random(M.n_vertices))
    ref = M.T.matvec(y)
    with kd.use_policy(PALLAS):
        got = M.T.matvec(y)
    assert kd.stats()["gather"]["pallas"] == 1
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_smoothing_parity_and_mask_fallback(dtype):
    rng = np.random.default_rng(6)
    v = jnp.asarray(rng.random(500), dtype)
    eta = jnp.asarray(80.0, dtype)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    sx_ref, wx_ref = smax_and_weights(v, eta)
    sn_ref, wn_ref = smin_and_weights(v, eta)
    mask = jnp.asarray(rng.random(500) > 0.5)
    kd.reset_stats()  # the reference calls above ticked the xla counter
    with kd.use_policy(PALLAS):
        sx, wx = smax_and_weights(v, eta)
        sn, wn = smin_and_weights(v, eta)
        sm, wm = smax_and_weights(v, eta, where=mask)
    s = kd.stats()["softmax"]
    assert s["pallas"] == 2  # the two unmasked calls
    assert s["xla"] == 0  # masked call never reaches choose(): hard fallback
    np.testing.assert_allclose(float(sx), float(sx_ref), rtol=tol)
    np.testing.assert_allclose(np.asarray(wx), np.asarray(wx_ref), atol=tol)
    np.testing.assert_allclose(float(sn), float(sn_ref), rtol=tol)
    np.testing.assert_allclose(np.asarray(wn), np.asarray(wn_ref), atol=tol)
    sm_ref, wm_ref = smax_and_weights(v, eta, where=mask)
    np.testing.assert_array_equal(np.asarray(wm), np.asarray(wm_ref))
    assert float(sm) == float(sm_ref)


def test_probe_fn_parity_and_mask_fallback():
    rng = np.random.default_rng(7)
    m, k = 300, 200
    y = jnp.asarray(rng.random(m))
    z = jnp.asarray(rng.random(k))
    dy = jnp.asarray(rng.random(m) * 1e-3)
    dz = jnp.asarray(rng.random(k) * 1e-3)
    eta = 60.0
    alpha = jnp.asarray(5.0)
    # with_grad: the XLA path leaves dphi/dpsi at 0 unless asked; the
    # kernel path always gets the Newton slopes for free
    ref = make_probe_fn(y, z, dy, dz, eta, with_grad=True)(alpha)
    kd.reset_stats()
    with kd.use_policy(PALLAS):
        got = make_probe_fn(y, z, dy, dz, eta)(alpha)
        c_mask = jnp.asarray(rng.random(k) > 0.5)
        masked = make_probe_fn(y, z, dy, dz, eta, c_mask=c_mask)(alpha)
    assert kd.stats()["probe"]["pallas"] == 1  # one probe_fn construction
    for a, b in zip(got, ref):
        np.testing.assert_allclose(float(a), float(b), rtol=1e-9, atol=1e-12)
    ref_masked = make_probe_fn(y, z, dy, dz, eta, c_mask=c_mask)(alpha)
    for a, b in zip(masked, ref_masked):
        assert float(a) == float(b)  # masked path is untouched XLA code


# -- 4. end to end ----------------------------------------------------------
FAMILIES = ["match", "vcover", "dom-set", "dense-sub"]


@pytest.mark.parametrize("family", FAMILIES)
def test_solve_pallas_matches_xla(family):
    from repro.api import MWUOptions, Solver

    prob = build(family, grid2d(4))
    sols, stats = {}, {}
    for be in ["xla", "pallas"]:
        kd.reset_stats()
        opts = MWUOptions(eps=0.15, step_rule="newton", max_iter=20000, kernel_backend=be)
        sols[be] = Solver(opts, batch_width=4).solve(prob)
        stats[be] = kd.stats()
    a, b = sols["xla"], sols["pallas"]
    assert a.feasible and b.feasible
    # the certified binary-search bound is a discrete quantity: identical
    assert b.bound == pytest.approx(a.bound, rel=1e-5)
    # objectives agree at the eps guarantee level (trajectories may
    # diverge in ulps through the branchy step-size search)
    assert b.objective == pytest.approx(a.objective, rel=2 * opts.eps)
    # xla run must not touch pallas; pallas run must be genuinely active
    assert all(d["pallas"] == 0 for d in stats["xla"].values())
    sp = stats["pallas"]
    active = {"softmax", "probe", "axpy"}
    if family != "dom-set":  # dom-set's ops are scatter-based (no gather)
        active.add("gather")
    for op in active:
        assert sp[op]["pallas"] > 0, (family, op, sp)
        assert sp[op]["xla"] == 0, (family, op, sp)


def test_solve_batch_pallas_backend_vmaps():
    """solve_batch vmaps the whole loop; pallas backend must still work."""
    from repro.api import MWUOptions
    from repro.api.solver import _feasibility_batch

    prob = build("match", grid2d(4))
    out = {}
    for be in ["xla", "pallas"]:
        opts = MWUOptions(eps=0.2, step_rule="newton", max_iter=5000, kernel_backend=be)
        kernels = kd.resolve(be)
        res = _feasibility_batch(
            prob, jnp.asarray([4.0, 8.0, 12.0, 16.0]), opts, None, kernels=kernels
        )
        out[be] = np.asarray(res.status)
    # batched lanes share the vmapped XLA rule → identical feasibility calls
    np.testing.assert_array_equal(out["pallas"], out["xla"])
