"""repro.lpserve: bucket policy, padding parity, continuous-batching engine.

The load-bearing claims, in dependency order:

1. the bucket policy rounds request dims onto a small ladder;
2. *padding parity* — a Problem padded into a larger bucket certifies
   the same objective (within the (1+eps) band) as the unpadded solve,
   per problem family;
3. padded problems stack (``stack_problems``) and mismatched ones raise
   ValueErrors naming the offending field/leaf;
4. the incremental :class:`BoundSearch` reproduces ``Solver.solve`` at
   ``batch_width=1`` exactly;
5. end-to-end: mixed-size requests through :class:`LPEngine` return
   per-request Solutions matching sequential solves, with fewer batch
   launches than requests (continuous batching actually batches).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import MWUOptions, Problem, Solver, stack_problems
from repro.core import Dense
from repro.graphs import Graph, build, erdos
from repro.graphs.problems import generalized_matching_problem
from repro.lpserve import (
    BoundSearch,
    BucketPolicy,
    BucketSpec,
    LPEngine,
    LPServeConfig,
    pad_problem,
    pad_problems,
    problem_dims,
)

EPS = 0.1
OPTS = MWUOptions(eps=EPS, step_rule="newton", max_iter=20000)

# three size tiers -> >= 3 distinct graph shapes in the engine tests
SIZE_TIERS = [(40, 100), (60, 160), (80, 220)]


def _tier_problems(family: str, count: int):
    return [
        build(family, erdos(*SIZE_TIERS[i % len(SIZE_TIERS)], seed=i))
        for i in range(count)
    ]


def _value(prob: Problem, sol) -> float:
    # densest-subgraph reports its optimum through the certified bound
    return float(sol.bound if prob.name == "dense-sub" else sol.objective)


# --------------------------------------------------------------- policy --
def test_bucket_policy_geometric_ladder():
    pol = BucketPolicy(vertex_floor=64, edge_floor=256, growth=2.0)
    assert pol.bucket_for(10, 100) == BucketSpec(64, 256)
    assert pol.bucket_for(64, 256) == BucketSpec(64, 256)  # exact rung
    assert pol.bucket_for(65, 257) == BucketSpec(128, 512)
    assert pol.bucket_for(300, 5000) == BucketSpec(512, 8192)


def test_bucket_policy_explicit_ladder_wins():
    pol = BucketPolicy(vertex_sizes=(100, 200), edge_sizes=(500,))
    assert pol.bucket_for(150, 400) == BucketSpec(200, 500)
    with pytest.raises(ValueError, match="exceeds the largest"):
        pol.bucket_for(201, 400)


def test_bucket_policy_validation():
    with pytest.raises(ValueError, match="growth"):
        BucketPolicy(growth=1.0)
    with pytest.raises(ValueError, match="sorted"):
        BucketPolicy(vertex_sizes=(200, 100))


@pytest.mark.parametrize("family", ["match", "vcover", "dom-set", "dense-sub"])
def test_problem_dims_from_operators(family):
    g = erdos(40, 100, seed=0)
    prob = build(family, g)
    assert problem_dims(prob) == (40, 100)
    # still inferable once the pytree roundtrip drops the Graph handle
    leaves, tree = jax.tree_util.tree_flatten(prob)
    assert problem_dims(jax.tree_util.tree_unflatten(tree, leaves)) == (40, 100)


# ------------------------------------------------------- padding parity --
@pytest.mark.parametrize("family", ["match", "vcover", "dom-set", "dense-sub"])
def test_padding_parity_certified_objective(family):
    """A padded Problem must certify the same objective as the unpadded
    one: padded edges/rows are masked out, so the feasible set over real
    variables is unchanged and the identical probe sequence certifies
    the identical bound."""
    g = erdos(40, 100, seed=3)
    prob = build(family, g)
    padded = pad_problem(prob, BucketSpec(64, 256))
    # the padded OPERATORS live on bucket dims; the source graph handle
    # (and hence problem_dims, which prefers it) still reports the request
    leaves, tree = jax.tree_util.tree_flatten(padded)
    assert problem_dims(jax.tree_util.tree_unflatten(tree, leaves)) == (64, 256)
    assert problem_dims(padded) == problem_dims(prob)
    assert padded.lo == prob.lo and padded.hi == prob.hi

    solver = Solver(OPTS, batch_width=1)
    ref = solver.solve(prob)
    sol = solver.solve(padded)
    assert ref.found and sol.found
    v_ref, v_pad = _value(prob, ref), _value(prob, sol)
    assert abs(v_pad - v_ref) <= EPS * max(abs(v_ref), 1.0)
    # padded coordinates never receive a gradient step: they stay frozen
    # at the (uniform) MWU init value instead of tracking the solve
    assert np.ptp(np.asarray(sol.x)[prob.n_vars:]) == 0.0


def test_padding_parity_feasibility_status():
    """Per-probe parity: the padded LP answers every bound the same way."""
    g = erdos(50, 120, seed=1)
    prob = build("match", g)
    padded = pad_problem(prob, BucketSpec(64, 256))
    solver = Solver(OPTS)
    for b in np.geomspace(float(prob.lo), float(prob.hi), 3):
        r0 = solver.feasible(prob, float(b))
        r1 = solver.feasible(padded, float(b))
        assert int(r0.status) == int(r1.status), f"status flipped at bound {b}"


def test_pad_problem_rejects_too_small_bucket_and_callable():
    prob = build("match", erdos(40, 100, seed=0))
    with pytest.raises(ValueError, match="does not fit"):
        pad_problem(prob, BucketSpec(64, 64))
    bad = dataclasses.replace(prob, bound_mode="callable", make_ops=lambda b: None)
    with pytest.raises(ValueError, match="callable"):
        pad_problem(bad, BucketSpec(64, 256))


def test_feasibility_only_problem_pads():
    """gen-match exercises the VStack + box-Coo padding rules."""
    g = Graph.from_edges(6, np.array([[0, i] for i in range(1, 6)]), "star6")
    lb = np.zeros(6)
    lb[0] = 2.0
    prob = generalized_matching_problem(g, lb, np.full(6, 3.0))
    padded = pad_problem(prob, BucketSpec(16, 16))
    sol = Solver(OPTS).solve(padded)
    assert sol.feasible
    assert np.isnan(sol.objective)
    assert np.ptp(np.asarray(sol.x)[prob.n_vars:]) == 0.0  # frozen at init


# ------------------------------------------------------------- stacking --
def test_pad_problems_stack_and_batch():
    """Mixed-size problems padded into one bucket run as ONE instance
    batch, and every lane agrees with its sequential probe."""
    probs = _tier_problems("match", 3)
    padded, bucket = pad_problems(probs)
    assert bucket == BucketSpec(128, 256)
    stacked = stack_problems(padded)  # would raise without padding
    bounds = [float(np.sqrt(float(p.lo) * float(p.hi))) for p in probs]
    solver = Solver(OPTS)
    batch = solver.solve_batch(stacked, jnp.asarray(bounds), batched_problem=True)
    assert batch.status.shape == (3,)
    for j, (p, b) in enumerate(zip(probs, bounds)):
        res = solver.feasible(p, b)
        assert int(res.status) == int(np.asarray(batch.status)[j])


def test_stack_problems_names_mismatched_static_field():
    probs = _tier_problems("match", 2)  # different sizes -> different n_vars
    with pytest.raises(ValueError, match=r"static field 'n_vars'"):
        stack_problems(probs)


def test_stack_problems_names_mismatched_structure():
    pa = Problem(name="x", kind="packing", sense="max",
                 bound_mode="objective_covering", P=Dense(jnp.ones((2, 3))),
                 c=jnp.ones((3,)))
    pb = dataclasses.replace(pa, C=Dense(jnp.ones((2, 3))))
    with pytest.raises(ValueError, match="pytree structure"):
        stack_problems([pa, pb])


def test_stack_problems_names_mismatched_leaf_shape():
    pa = Problem(name="x", kind="packing", sense="max",
                 bound_mode="objective_covering", P=Dense(jnp.ones((2, 3))),
                 c=jnp.ones((3,)))
    pb = dataclasses.replace(pa, P=Dense(jnp.ones((2, 4))), c=jnp.ones((4,)))
    # the keyed pytree registration makes the message name the leaf path
    with pytest.raises(ValueError, match=r"\.P\.mat.*pad_problems"):
        stack_problems([pa, pb])
    with pytest.raises(ValueError, match="at least one"):
        stack_problems([])


# --------------------------------------------------------- bound search --
def test_bound_search_replays_sequential_solver():
    """Driven by the same feasibility oracle, the incremental search
    must reproduce Solver.solve at batch_width=1 *exactly* — identical
    probe sequence, identical certified solution."""
    for family in ("match", "vcover"):
        prob = build(family, erdos(50, 120, seed=2))
        seq = Solver(OPTS, batch_width=1)
        ref = seq.solve(prob)
        bs = BoundSearch(prob, rel_tol=OPTS.eps / 2, max_calls=64)
        while not bs.done:
            b = bs.next_bound()
            bs.update(b, seq.feasible(prob, b))
        assert bs.solution.found == ref.found
        assert bs.solution.feasibility_calls == ref.feasibility_calls
        assert bs.solution.objective == pytest.approx(ref.objective, rel=1e-12)


def test_bound_search_not_found():
    prob = build("match", erdos(40, 100, seed=0))
    # a matching LP on 40 vertices can never reach objective 40
    bad = dataclasses.replace(prob, lo=40.0, hi=80.0)
    seq = Solver(OPTS, batch_width=1)
    bs = BoundSearch(bad, rel_tol=OPTS.eps / 2, max_calls=64)
    while not bs.done:
        b = bs.next_bound()
        bs.update(b, seq.feasible(bad, b))
    assert not bs.solution.found
    assert bs.solution.objective == 0.0


# --------------------------------------------------------------- engine --
def test_engine_end_to_end_mixed_sizes():
    """The acceptance test: N requests spanning >= 3 distinct graph
    sizes, solved through the engine, match sequential Solver.solve
    objectives — with fewer batch launches than requests."""
    probs = _tier_problems("match", 12)
    assert len({problem_dims(p) for p in probs}) >= 3
    engine = LPEngine(LPServeConfig(opts=OPTS, lanes=8))
    sols = engine.solve_many(probs)

    seq = Solver(OPTS, batch_width=1)
    for i, (p, sol) in enumerate(zip(probs, sols)):
        ref = seq.solve(p)
        assert sol.feasible, f"request {i} not feasible"
        assert abs(sol.objective - ref.objective) <= 3.0 * EPS * max(ref.objective, 1.0), (
            f"request {i}: engine {sol.objective} vs sequential {ref.objective}"
        )
        assert np.asarray(sol.x).shape == (p.n_vars,)  # unpadded

    st = engine.stats()
    assert st["requests"] == st["completed"] == len(probs)
    assert st["batches"] < len(probs), "continuous batching never batched"
    assert st["feasibility_calls"] >= len(probs)
    assert 0.0 < st["lane_occupancy"] <= 1.0
    assert st["compile_cache_hits"] >= 1  # bucket shapes were reused
    assert st["compiles"] <= len({(p.name, problem_dims(p)) for p in probs})


def test_engine_mixed_families_and_stats_shape():
    probs = [
        build("match", erdos(40, 100, seed=0)),
        build("vcover", erdos(40, 100, seed=1)),
        build("match", erdos(60, 160, seed=2)),
        build("vcover", erdos(60, 160, seed=3)),
    ]
    engine = LPEngine(LPServeConfig(opts=OPTS, lanes=4))
    rids = [engine.submit(p) for p in probs]
    engine.run()
    sols = [engine.result(r) for r in rids]
    assert all(s is not None and s.feasible for s in sols)

    st = engine.stats()
    assert st["not_found"] == 0
    assert set(st["buckets"]) >= {"match/V64xE256", "vcover/V64xE256"}
    for b in st["buckets"].values():
        assert b["completed"] == b["requests"]
        assert 0.0 <= b["padding_waste"] < 1.0
    assert np.isfinite(st["latency_p50_s"]) and np.isfinite(st["latency_p99_s"])
    assert st["latency_p50_s"] <= st["latency_p99_s"] + 1e-12


def test_engine_not_found_request():
    prob = build("match", erdos(40, 100, seed=0))
    bad = dataclasses.replace(prob, lo=40.0, hi=80.0)
    engine = LPEngine(LPServeConfig(opts=OPTS, lanes=2))
    sols = engine.solve_many([bad])
    assert not sols[0].found
    assert sols[0].objective == 0.0
    assert engine.stats()["not_found"] == 1


def test_engine_feasibility_only_request():
    g = Graph.from_edges(6, np.array([[0, i] for i in range(1, 6)]), "star6")
    lb = np.zeros(6)
    lb[0] = 2.0
    prob = generalized_matching_problem(g, lb, np.full(6, 3.0))
    engine = LPEngine(LPServeConfig(
        opts=OPTS, lanes=2, policy=BucketPolicy(vertex_floor=8, edge_floor=8)))
    sols = engine.solve_many([prob])
    assert sols[0].feasible
    assert np.isnan(sols[0].objective)
    assert sols[0].feasibility_calls == 1  # bound_mode="none": single probe


def test_engine_unpadded_lanes_mode():
    """pad_lanes=False launches exactly the active lane count."""
    probs = [build("match", erdos(40, 100, seed=s)) for s in (0, 1)]
    engine = LPEngine(LPServeConfig(opts=OPTS, lanes=4, pad_lanes=False))
    sols = engine.solve_many(probs)
    assert all(s.feasible for s in sols)
    assert engine.stats()["lane_occupancy"] == 1.0


def test_engine_rejects_bad_config():
    with pytest.raises(ValueError, match="lanes"):
        LPServeConfig(lanes=0)
