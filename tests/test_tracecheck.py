"""repro.tracecheck: the gate passes on the real tree and fails on
deliberately broken invariants (ISSUE 9 acceptance criteria)."""
import jax
import jax.numpy as jnp
import pytest

from repro.api.solver import Solver
from repro.core.mwu import MWUOptions
from repro.graphs import generators, problems
from repro.kernels import dispatch as _kd
from repro.tracecheck import Finding, TraceArtifact, run_rules
from repro.tracecheck.capture import capture_case, solve_dtype
from repro.tracecheck.matrix import Case, default_matrix
from repro.tracecheck.report import build_report, load_baseline, split_findings
from repro.tracecheck.rules import (
    DtypeRule,
    HostCallbackRule,
    KernelPathRule,
    LoopCollectivesRule,
    TripCountRule,
    VmemFootprintRule,
)


@pytest.fixture(scope="module")
def problem():
    return problems.build("match", generators.erdos(24, 60, seed=7))


@pytest.fixture(scope="module")
def solver():
    return Solver(MWUOptions())


def _bound(problem):
    lo, hi = float(problem.lo), float(problem.hi)
    return lo * (hi / lo) ** 0.5


# --------------------------------------------------------- clean passes --
def test_clean_solve_artifacts_have_no_findings():
    for backend in ("xla", "pallas"):
        case = Case("solve", "match", backend)
        art = capture_case(case)
        assert run_rules([art]) == [], f"backend={backend}"


def test_quick_matrix_single_device_passes():
    """The bench-shared quick sweep is clean on the current tree (cases
    needing more devices than the test session's one are skipped)."""
    arts = []
    for case in default_matrix(quick=True):
        got = capture_case(case)
        if got is None:
            continue
        arts.extend(got if isinstance(got, list) else [got])
    assert arts, "quick matrix captured nothing"
    findings = run_rules(arts)
    assert findings == [], [f.fingerprint for f in findings]


# ------------------------------------------------- broken: kernel path --
def test_kernel_path_missing_pallas_fails(problem, solver):
    """kernel_backend=pallas with the custom-call stripped: lint an XLA
    trace under a pallas expectation -> the kernel-path rule must fire."""
    jaxpr = solver.jaxpr_feasible(problem, _bound(problem))  # xla trace
    art = TraceArtifact(
        name="broken:pallas-stripped",
        jaxpr=jaxpr,
        policy=_kd.resolve("pallas"),
        expect={"pallas_in_loop": True, "collectives": {}, "dtype": solve_dtype(problem, _bound(problem))},
    )
    fps = [f.fingerprint for f in KernelPathRule().check(art)]
    assert "kernel-path::broken:pallas-stripped::missing" in fps


def test_kernel_path_unexpected_pallas_fails(problem):
    """A pallas_call on a path declared xla/batched is also a violation."""
    pallas_solver = Solver(MWUOptions(kernel_backend="pallas"))
    jaxpr = pallas_solver.jaxpr_feasible(problem, _bound(problem))
    art = TraceArtifact(
        name="broken:unexpected-pallas", jaxpr=jaxpr, expect={"pallas_in_loop": False}
    )
    fps = [f.fingerprint for f in KernelPathRule().check(art)]
    assert "kernel-path::broken:unexpected-pallas::unexpected" in fps


# ------------------------------------------- broken: loop collectives --
def test_extra_collective_in_loop_fails():
    """A psum traced into the while body of a plan that declares none."""
    from jax.sharding import PartitionSpec as P

    from repro.dist.mesh import POD_AXIS, MeshPlan

    plan = MeshPlan()  # identity plan: declared in-loop collectives = {}

    def body(x):
        def cond(s):
            return s[0] < 3

        def step(s):
            return s[0] + 1, jax.lax.psum(s[1], POD_AXIS)

        return jax.lax.while_loop(cond, step, (0, x))[1]

    fn = plan.shard_map(body, in_specs=(P(),), out_specs=P())
    jaxpr = jax.make_jaxpr(fn)(jnp.ones(4))
    art = TraceArtifact(name="broken:psum", jaxpr=jaxpr, expect={"collectives": {}})
    findings = LoopCollectivesRule().check(art)
    assert len(findings) == 1
    assert findings[0].detail["got"] == {"psum": 1}


def test_matching_collectives_pass():
    from jax.sharding import PartitionSpec as P

    from repro.dist.mesh import POD_AXIS, MeshPlan

    plan = MeshPlan()

    def body(x):
        def cond(s):
            return s[0] < 3

        def step(s):
            return s[0] + 1, jax.lax.psum(s[1], POD_AXIS)

        return jax.lax.while_loop(cond, step, (0, x))[1]

    fn = plan.shard_map(body, in_specs=(P(),), out_specs=P())
    jaxpr = jax.make_jaxpr(fn)(jnp.ones(4))
    art = TraceArtifact(
        name="ok:psum", jaxpr=jaxpr, expect={"collectives": {"psum": 1}}
    )
    assert LoopCollectivesRule().check(art) == []


# ------------------------------------------------ broken: host callback --
def test_callback_inside_loop_fails():
    def f(x):
        def cond(s):
            return s < 3.0

        def step(s):
            jax.debug.callback(lambda v: None, s)
            return s + 1.0

        return jax.lax.while_loop(cond, step, x)

    jaxpr = jax.make_jaxpr(f)(jnp.float32(0.0))
    art = TraceArtifact(name="broken:callback", jaxpr=jaxpr, expect={})
    findings = HostCallbackRule().check(art)
    assert len(findings) == 1
    assert findings[0].key == "debug_callback"
    assert findings[0].severity == "error"


def test_traced_solve_io_callback_is_allowed(problem, solver):
    """The opt-in trace hook's io_callback must NOT trip the rule."""
    jaxpr = solver.jaxpr_feasible(problem, _bound(problem), trace=True)
    art = TraceArtifact(name="traced", jaxpr=jaxpr, expect={"traced": True})
    assert HostCallbackRule().check(art) == []


# --------------------------------------------------- broken: vmem budget --
def test_vmem_footprint_over_budget_fails():
    """A gather holding 2x the vertex limit resident must blow the budget
    (abstract trace only: nothing this size is allocated)."""
    from repro.kernels.incidence_gather.kernel import incidence_gather_pallas

    n = 2 * _kd.vmem_vertex_limit(jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda u, v, w: incidence_gather_pallas(u, v, w, interpret=True)
    )(
        jax.ShapeDtypeStruct((4096,), jnp.int32),
        jax.ShapeDtypeStruct((4096,), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    )
    art = TraceArtifact(
        name="broken:vmem", jaxpr=jaxpr, expect={"pallas_anywhere": True}
    )
    findings = VmemFootprintRule().check(art)
    assert len(findings) == 1
    assert findings[0].detail["bytes"] > findings[0].detail["budget"]


def test_vmem_footprint_at_gate_limit_passes():
    art = capture_case(Case("kernel", op="gather"))
    assert VmemFootprintRule().check(art) == []


# -------------------------------------- synthetic HLO: trip count, dtype --
_HLO = """\
HloModule synth

%cond (p: (s32[])) -> pred[] {
  %p = (s32[]) parameter(0)
  %it = s32[] get-tuple-element((s32[]) %p), index=0
  %junk = s32[] constant(424242)
  %k = s32[] constant(MAXITER)
  ROOT %lt = pred[] compare(s32[] %it, s32[] %k), direction=LT
}

%body (q: (s32[])) -> (s32[]) {
  %q = (s32[]) parameter(0)
  %i = s32[] get-tuple-element((s32[]) %q), index=0
  %one = s32[] constant(1)
  ROOT %t = (s32[]) tuple(s32[] add(s32[] %i, s32[] %one))
}

ENTRY %main (x: s32[]) -> (s32[]) {
  %x = s32[] parameter(0)
  %z = s32[] constant(0)
  %c0 = (s32[]) tuple(s32[] %z)
  ROOT %w = (s32[]) while((s32[]) %c0), condition=%cond, body=%body
}
"""


def test_trip_count_matches_max_iter():
    opts = MWUOptions(max_iter=321)
    art = TraceArtifact(
        name="synth", hlo_text=_HLO.replace("MAXITER", "321"), opts=opts,
        expect={"max_iter": 321},
    )
    assert TripCountRule().check(art) == []


def test_trip_count_drift_fails():
    """Compiled cap != MWUOptions.max_iter (and the unrelated 424242
    constant must not mask the drift by inflating the recovered bound)."""
    opts = MWUOptions(max_iter=500)
    art = TraceArtifact(
        name="synth-drift", hlo_text=_HLO.replace("MAXITER", "321"), opts=opts,
        expect={"max_iter": 500},
    )
    findings = TripCountRule().check(art)
    assert len(findings) == 1
    assert findings[0].detail["trips"] == [321]


def test_dtype_rule_flags_f64_leak():
    def f(x):
        return x * 1.5e300  # forces an f64 constant under x64

    jaxpr = jax.make_jaxpr(f)(jnp.float64(1.0))
    art = TraceArtifact(name="leak", jaxpr=jaxpr, expect={"dtype": "float32"})
    fps = [f.fingerprint for f in DtypeRule().check(art)]
    assert "dtype-discipline::leak::jaxpr" in fps


def test_dtype_rule_respects_f64_problems():
    """An f64 solve (x64 test sessions) has nothing wider to leak into."""
    jaxpr = jax.make_jaxpr(lambda x: x * 2.0)(jnp.float64(1.0))
    art = TraceArtifact(name="f64-ok", jaxpr=jaxpr, expect={"dtype": "float64"})
    assert DtypeRule().check(art) == []


# -------------------------------------------------------- baseline gate --
def test_baseline_suppresses_known_findings(tmp_path):
    f1 = Finding(rule="kernel-path", severity="error", artifact="a", message="m", key="missing")
    f2 = Finding(rule="trip-count", severity="error", artifact="b", message="m")
    allow = {f1.fingerprint}
    new, old = split_findings([f1, f2], allow)
    assert [x.fingerprint for x in new] == [f2.fingerprint]
    assert [x.fingerprint for x in old] == [f1.fingerprint]

    cases = [Case("solve", "match", "xla")]
    rep = build_report(cases, [], [f1], allow)
    assert rep["ok"] and rep["n_baselined"] == 1
    rep = build_report(cases, [], [f1, f2], allow)
    assert not rep["ok"] and rep["n_new_errors"] == 1

    p = tmp_path / "baseline.json"
    p.write_text('{"allow": ["kernel-path::a::missing"]}')
    assert load_baseline(str(p)) == {"kernel-path::a::missing"}


def test_shipped_baseline_is_empty():
    """The tree is clean: the checked-in allowlist must stay empty."""
    assert load_baseline() == set()


# ------------------------------------------------------- lpserve audit --
def test_lpserve_audit_does_not_mutate_engine():
    from repro.lpserve import LPEngine, LPServeConfig

    eng = LPEngine(LPServeConfig(lanes=4))
    for seed in (1, 2):
        eng.submit(problems.build("match", generators.erdos(24, 60, seed=seed)))
    before = {k: (len(s.queue), len(s.active)) for k, s in eng._buckets.items()}
    launches = eng.audit_launches()
    assert launches  # at least one dispatch key assembled
    for key, (stacked, bounds) in launches.items():
        assert jnp.shape(bounds)[0] == 4  # padded to the lane count
        assert jnp.shape(stacked.c)[0] == 4
    after = {k: (len(s.queue), len(s.active)) for k, s in eng._buckets.items()}
    assert before == after
    # searches untouched: the engine still drains to completion
    sols = eng.run()
    assert len(sols) == 2
