"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode).

Every Pallas kernel is exercised over aligned and ragged (non-tile-
multiple) shapes and f32/f64 dtypes, as the deliverable requires. The
MWU kernels are dtype-preserving (the solver runs f64 under x64, f32
otherwise), so each sweep runs in both dtypes with tolerances scaled to
the element size. Dispatch-layer behaviour (policies, custom_vmap,
operator wiring, end-to-end solves) lives in tests/test_kernel_dispatch.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.axpy_reduce.ops import axpy_reduce
from repro.kernels.axpy_reduce.ref import axpy_reduce_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.incidence_gather.ops import incidence_gather
from repro.kernels.incidence_gather.ref import incidence_gather_ref
from repro.kernels.linesearch_probe.ops import linesearch_probe
from repro.kernels.linesearch_probe.ref import linesearch_probe_ref
from repro.kernels.softmax_weights.ops import softmax_weights
from repro.kernels.softmax_weights.ref import softmax_weights_ref
from repro.models.layers import attention as att

SIZES = [3, 127, 1024, 1030, 4096, 9999]
DTYPES = [jnp.float32, jnp.float64]

# tile-wise vs global reduction order: ~1e-4 absolute on f32 at eta~200,
# vanishing at f64.
TOLS = {jnp.float32: 1e-4, jnp.float64: 1e-10}


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("sign", [1.0, -1.0])
def test_softmax_weights(n, sign, dtype):
    rng = np.random.default_rng(n)
    tol = TOLS[dtype]
    v = jnp.asarray(rng.standard_normal(n), dtype)
    eta = jnp.asarray(211.0, dtype)
    lse_p, w_p = softmax_weights(v, eta, sign=sign, impl="pallas")
    lse_r, w_r = softmax_weights_ref(v, eta, sign)
    assert w_p.dtype == dtype and lse_p.dtype == dtype
    np.testing.assert_allclose(float(lse_p), float(lse_r), rtol=tol)
    np.testing.assert_allclose(np.asarray(w_p), np.asarray(w_r), atol=tol)
    np.testing.assert_allclose(float(w_p.sum()), 1.0, rtol=tol)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", SIZES)
def test_axpy_reduce(n, dtype):
    rng = np.random.default_rng(n)
    tol = min(TOLS[dtype], 1e-6)
    y = jnp.asarray(rng.standard_normal(n), dtype)
    dy = jnp.asarray(rng.random(n), dtype)
    a = jnp.asarray(3.25, dtype)
    out_p, mn_p, mx_p = axpy_reduce(y, dy, a, impl="pallas")
    out_r, mn_r, mx_r = axpy_reduce_ref(y, dy, a)
    assert out_p.dtype == dtype
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), atol=tol)
    assert abs(float(mn_p - mn_r)) < tol
    assert abs(float(mx_p - mx_r)) < tol


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("E,n", [(17, 5), (2048, 300), (4100, 999)])
def test_incidence_gather(E, n, dtype):
    rng = np.random.default_rng(E)
    u = jnp.asarray(rng.integers(0, n, E), jnp.int32)
    v = jnp.asarray(rng.integers(0, n, E), jnp.int32)
    w = jnp.asarray(rng.standard_normal(n), dtype)
    g_p = incidence_gather(u, v, w, impl="pallas")
    g_r = incidence_gather_ref(u, v, w)
    # pure gather+add: dtype-preserving and exact in both dtypes
    assert g_p.dtype == dtype
    np.testing.assert_array_equal(np.asarray(g_p), np.asarray(g_r))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", [9, 1024, 3333])
@pytest.mark.parametrize("sign", [1.0, -1.0])
def test_linesearch_probe(n, sign, dtype):
    rng = np.random.default_rng(n)
    tol = TOLS[dtype]
    y = jnp.asarray(rng.random(n), dtype)
    dy = jnp.asarray(rng.random(n) * 1e-3, dtype)
    alpha = jnp.asarray(7.5, dtype)
    eta = jnp.asarray(97.0, dtype)
    p = linesearch_probe(y, dy, alpha, eta, sign=sign, impl="pallas")
    r = linesearch_probe_ref(y, dy, alpha, eta, sign)
    assert all(a.dtype == dtype for a in p)
    for a, b in zip(p, r):
        assert abs(float(a) - float(b)) < tol, (sign, float(a), float(b))


@pytest.mark.parametrize("S", [16, 63, 130])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 24), (False, None)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, causal, window, dtype):
    rng = np.random.default_rng(S)
    B, Hq, Hkv, dh = 2, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, Hq, dh)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), dtype)
    out_p = flash_attention(q, k, v, causal=causal, window=window,
                            block_q=32, block_k=32, impl="pallas")
    pos = jnp.arange(S)
    ref = att._sdpa_dense(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        pos[None].repeat(B, 0), pos, causal=causal, window=window,
    )
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out_p, np.float32), np.asarray(ref), atol=tol, rtol=tol
    )


def test_flash_attention_gqa_groups():
    """GQA group folding: each q head attends its own kv head."""
    rng = np.random.default_rng(0)
    B, S, Hq, Hkv, dh = 1, 32, 8, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, Hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16, impl="pallas")
    ref = flash_attention_ref(
        jnp.repeat(q.transpose(0, 2, 1, 3), 1, 1).reshape(B * Hq, S, dh),
        jnp.repeat(k.transpose(0, 2, 1, 3), Hq // Hkv, axis=1).reshape(B * Hq, S, dh),
        jnp.repeat(v.transpose(0, 2, 1, 3), Hq // Hkv, axis=1).reshape(B * Hq, S, dh),
        causal=True,
    ).reshape(B, Hq, S, dh).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
