"""Line-search invariants (paper §4: eq. 16, Prop. 4.2, Alg. 3)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property-based tests need the 'test' extra")
from hypothesis import given, settings, strategies as st

from repro.core.stepsize import (
    binary_search_step,
    make_probe_fn,
    newton_step,
    standard_step,
)


def random_state(rng, mp=12, mc=9, scale=0.3):
    """A plausible mid-solve MWU state: y,z in (0,1), nonneg steps."""
    y = jnp.asarray(rng.random(mp) * scale)
    z = jnp.asarray(rng.random(mc) * scale)
    dy = jnp.asarray(rng.random(mp) * 1e-3)
    dz = jnp.asarray(rng.random(mc) * 1e-3 + 1e-5)
    eta = jnp.asarray(50.0)
    return y, z, dy, dz, eta


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_f_monotone_decreasing(seed):
    """Prop 4.2: f(alpha) = Phi/Psi is monotone decreasing on R+."""
    rng = np.random.default_rng(seed)
    y, z, dy, dz, eta = random_state(rng)
    probe = make_probe_fn(y, z, dy, dz, eta)
    alphas = np.geomspace(0.25, 4096.0, 20)
    fs = np.array([float(probe(a).f) for a in alphas])
    fs = fs[np.isfinite(fs)]
    assert (np.diff(fs) <= 1e-9).all(), fs


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_binary_search_satisfies_invariant(seed):
    """An accepted step (alpha >= 1) obeys the bang-for-buck invariant
    f(alpha) >= 1. alpha < 1 means the solver declares INFEASIBLE and the
    step is never applied (Alg. 2 line 12), so no invariant is required."""
    rng = np.random.default_rng(seed)
    y, z, dy, dz, eta = random_state(rng)
    res = binary_search_step(y, z, dy, dz, eta, ls_eps=0.1)
    if float(res.alpha) < 1.0:
        return
    probe = make_probe_fn(y, z, dy, dz, eta)
    f = float(probe(res.alpha).f)
    assert bool(res.completes) or f >= 1.0 - 1e-7, (float(res.alpha), f)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_newton_satisfies_invariant(seed):
    rng = np.random.default_rng(seed)
    y, z, dy, dz, eta = random_state(rng)
    res = newton_step(y, z, dy, dz, eta, ls_eps=0.1)
    if float(res.alpha) < 1.0:
        return
    probe = make_probe_fn(y, z, dy, dz, eta)
    f = float(probe(res.alpha).f)
    assert bool(res.completes) or f >= 1.0 - 1e-7, (float(res.alpha), f)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_search_alpha_at_least_one_when_f1_ok(seed):
    """If f(1) >= 1 (feasible-direction case) the search returns alpha >= 1."""
    rng = np.random.default_rng(seed)
    y, z, dy, dz, eta = random_state(rng)
    probe = make_probe_fn(y, z, dy, dz, eta)
    if float(probe(jnp.asarray(1.0)).f) < 1.0:
        return
    for fn in (binary_search_step, newton_step):
        res = fn(y, z, dy, dz, eta, ls_eps=0.1)
        assert float(res.alpha) >= 1.0 - 1e-9


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_binary_beats_standard(seed):
    """Line search never returns a smaller step than the standard alpha=1
    when alpha=1 is admissible — that is the whole point of §4."""
    rng = np.random.default_rng(seed)
    y, z, dy, dz, eta = random_state(rng)
    probe = make_probe_fn(y, z, dy, dz, eta)
    if float(probe(jnp.asarray(1.0)).f) < 1.0:
        return
    res = binary_search_step(y, z, dy, dz, eta, ls_eps=0.1)
    std = standard_step(y, z, dy, dz, eta)
    if bool(res.completes):
        return  # completing steps are clamped to the smallest completing alpha
    assert float(res.alpha) >= float(std.alpha) - 1e-9


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_completion_does_not_overshoot(seed):
    """Completing steps return (nearly) the smallest covering-satisfying alpha."""
    rng = np.random.default_rng(seed)
    y, z, dy, dz, eta = random_state(rng)
    # force completion to be reachable: make dz large
    dz = dz * 1e5
    res = binary_search_step(y, z, dy, dz, eta, ls_eps=0.05)
    if not bool(res.completes):
        return
    mn = float(jnp.min(z + res.alpha * dz))
    assert mn >= 1.0 - 1e-9
    # halving the step (but not below 1) must NOT satisfy covering,
    # i.e. alpha is within ~2x of minimal
    half = max(float(res.alpha) * 0.5, 1.0)
    if half < float(res.alpha) * 0.99:
        mn_half = float(jnp.min(z + half * dz))
        assert mn_half < 1.0 + 0.25, (mn, mn_half)


def test_warm_start_reduces_probes():
    rng = np.random.default_rng(0)
    y, z, dy, dz, eta = random_state(rng)
    cold = binary_search_step(y, z, dy, dz, eta, ls_eps=0.1)
    warm = binary_search_step(y, z, dy, dz, eta, ls_eps=0.1, alpha0=cold.alpha)
    assert int(warm.probes) <= int(cold.probes)
