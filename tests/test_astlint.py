"""repro.tracecheck.astlint: each RPR code fires on a fixture and the
real tree is clean (ISSUE 10 acceptance criteria). Stdlib-only pass:
none of these tests may require jax."""
import os
import sys

from repro.tracecheck.astlint import (
    RPR_RULES,
    format_findings,
    lint_paths,
    lint_source,
    main as astlint_main,
)

_FIXTURE = '''\
import os
import warnings
from functools import partial

import jax
import jax.numpy as jnp


@jax.jit
def bad_backend_and_branch(x):
    backend = jax.default_backend()
    flag = os.environ.get("REPRO_FLAG")
    if x > 0:
        return x
    return -x


def widen(x):
    return x.astype(jnp.float64)


def stray_callback(x):
    jax.experimental.io_callback(print, None, x)
    return x


@partial(jax.jit, static_argnames=("ks",))
def jitted(x, *, ks):
    return x


def caller(x):
    return jitted(x, ks=[1, 2])


def old_api():
    warnings.warn("old_api is deprecated", DeprecationWarning)
'''


def _fixture_file(tmp_path):
    # the file must live under a core/ dir so the RPR003 scope applies
    d = tmp_path / "core"
    d.mkdir()
    p = d / "fixture.py"
    p.write_text(_FIXTURE)
    return p


def test_fixture_trips_every_rule(tmp_path):
    _fixture_file(tmp_path)
    findings = lint_paths([str(tmp_path)])
    codes = {f.code for f in findings}
    assert codes == set(RPR_RULES), format_findings(findings)
    # RPR001 fires for both the backend read and the env read
    assert sum(1 for f in findings if f.code == "RPR001") == 2


def test_ast_cli_exits_nonzero_on_fixture(tmp_path):
    _fixture_file(tmp_path)
    assert astlint_main([str(tmp_path)]) == 1


def test_source_tree_is_clean():
    root = os.path.join(os.path.dirname(__file__), os.pardir, "src", "repro")
    findings = lint_paths([os.path.abspath(root)])
    assert findings == [], format_findings(findings)


def test_package_cli_ast_defaults_to_clean_tree():
    """`python -m repro.tracecheck --ast` (no paths) lints the package."""
    from repro.tracecheck.__main__ import main

    assert main(["--ast"]) == 0


def test_noqa_suppresses_per_line():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    b = jax.default_backend()  # repro: noqa[RPR001]\n"
        "    return x\n"
    )
    assert lint_source(src, "mod.py") == []
    # without the annotation the same source is a finding
    assert lint_source(src.replace("  # repro: noqa[RPR001]", ""), "mod.py") != []


def test_static_args_and_shape_branches_are_not_tracer_branches():
    src = (
        "import jax\n"
        "from functools import partial\n"
        "@partial(jax.jit, static_argnames=('mode',))\n"
        "def f(x, mode):\n"
        "    if mode == 'fast':\n"
        "        return x\n"
        "    pad = (8 - x.shape[0] % 8) % 8\n"
        "    if pad:\n"
        "        return x\n"
        "    return -x\n"
    )
    assert lint_source(src, "mod.py") == []


def test_branch_on_derived_tracer_value_fires():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    y = x + 1\n"
        "    if y:\n"
        "        return y\n"
        "    return x\n"
    )
    findings = lint_source(src, "mod.py")
    assert [f.code for f in findings] == ["RPR002"]


def test_astlint_never_imports_jax():
    """The module must stay importable in the dependency-free lint job."""
    import importlib
    import subprocess

    mod = importlib.import_module("repro.tracecheck.astlint")
    prog = (
        "import sys; sys.path.insert(0, sys.argv[1]); "
        "from repro.tracecheck import astlint; "
        "assert 'jax' not in sys.modules, 'astlint pulled in jax'"
    )
    src = os.path.abspath(os.path.join(os.path.dirname(mod.__file__), "..", ".."))
    subprocess.run([sys.executable, "-c", prog, src], check=True, timeout=120)
