"""Distributed MWU (paper §5.2) — multi-device subprocess tests.

Each test spawns a fresh python with --xla_force_host_platform_device_count
so the main test session keeps its single device (dry-run isolation rule).
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path


SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(code: str, devices: int = 4, timeout=900, retries: int = 2):
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        f"import sys; sys.path.insert(0, {SRC!r})\n" + textwrap.dedent(code)
    )
    for attempt in range(retries + 1):
        res = subprocess.run(
            [sys.executable, "-c", prog], capture_output=True, text=True, timeout=timeout
        )
        if res.returncode == 0:
            return res.stdout
        # XLA-CPU collectives busy-wait; with many fabricated device
        # threads on few cores the 40 s rendezvous can spuriously time
        # out under load — retry those, fail everything else.
        if "rendezvous" not in res.stderr.lower() or attempt == retries:
            assert res.returncode == 0, f"stderr:\n{res.stderr[-3000:]}"
    return res.stdout


def test_dist_matching_matches_single_device():
    out = run_sub(
        """
        import jax, jax.numpy as jnp, json
        from repro.graphs import rgg
        from repro.graphs.baselines import greedy_maximal_matching
        from repro.sparsela.partition import partition_edges
        from repro.core.mwu_dist import dist_matching_solve
        from repro.core import MWUOptions, Status, solve, Incidence, OnesRow
        from repro.launch.mesh import make_mesh

        g = rgg(9, seed=1)
        bound = float(greedy_maximal_matching(g))
        mesh = make_mesh((2, 2), ("data", "model"))
        part = partition_edges(g, grid=2)
        res = dist_matching_solve(part, g.n, bound, mesh, eps=0.1, max_iter=5000)

        P = Incidence(u=jnp.asarray(g.u), v=jnp.asarray(g.v), n_vertices=g.n)
        C = OnesRow(c=jnp.ones((g.m,)), inv_bound=jnp.asarray(1.0 / bound))
        ref = solve(P, C, MWUOptions(eps=0.1, step_rule="binary", max_iter=5000))
        print(json.dumps({
            "dist_status": int(res.status), "ref_status": int(ref.status),
            "dist_obj": float(res.objective), "ref_obj": float(jnp.sum(ref.x)),
            "dist_max_px": float(res.max_px), "dist_iters": int(res.iters),
            "ref_iters": int(ref.iters),
        }))
        """
    )
    d = json.loads(out.strip().splitlines()[-1])
    assert d["dist_status"] == 1 and d["ref_status"] == 1  # FEASIBLE
    assert abs(d["dist_obj"] - d["ref_obj"]) / d["ref_obj"] < 0.15
    assert d["dist_max_px"] <= 1.1 + 1e-6
    assert abs(d["dist_iters"] - d["ref_iters"]) <= 10


def test_dist_infeasible_detection():
    out = run_sub(
        """
        import jax, json
        from repro.graphs import rgg
        from repro.sparsela.partition import partition_edges
        from repro.core.mwu_dist import dist_matching_solve
        from repro.launch.mesh import make_mesh

        g = rgg(8, seed=0)
        mesh = make_mesh((2, 2), ("data", "model"))
        part = partition_edges(g, grid=2)
        res = dist_matching_solve(part, g.n, g.n * 2.0, mesh, eps=0.1, max_iter=2000)
        print(json.dumps({"status": int(res.status)}))
        """
    )
    d = json.loads(out.strip().splitlines()[-1])
    assert d["status"] in (2, 3)  # INFEASIBLE / ITER_LIMIT


def test_pod_parallel_bounds():
    """(pod, data, model) mesh: two bounds solved concurrently — the
    beyond-paper pod-parallel binary search."""
    out = run_sub(
        """
        import jax, jax.numpy as jnp, json
        from repro.graphs import rgg
        from repro.graphs.baselines import greedy_maximal_matching
        from repro.sparsela.partition import partition_edges
        from repro.core.mwu_dist import make_pod_parallel_solver
        from repro.launch.mesh import make_mesh

        g = rgg(9, seed=1)
        gm = float(greedy_maximal_matching(g))
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        part = partition_edges(g, grid=2)
        fn = make_pod_parallel_solver(mesh, 2, part.block, g.n, g.m, max_iter=4000)
        bounds = jnp.asarray([gm, g.n * 2.0], jnp.float32)  # feasible, infeasible
        with mesh:
            status, iters, obj, max_px = jax.jit(fn)(
                bounds, jnp.asarray(part.u_loc), jnp.asarray(part.v_loc),
                jnp.asarray(part.mask))
        print(json.dumps({"status": [int(s) for s in status]}))
        """,
        devices=8,
    )
    d = json.loads(out.strip().splitlines()[-1])
    assert d["status"][0] == 1  # feasible bound
    assert d["status"][1] in (2, 3)  # infeasible bound


def test_partition_roundtrip():
    import numpy as np

    from repro.graphs import kron
    from repro.sparsela.partition import partition_edges

    g = kron(8, seed=3, edgefactor=8)
    part = partition_edges(g, grid=4)
    # every real edge appears exactly once with correct global ids
    got = []
    for i in range(4):
        for j in range(4):
            msk = part.mask[i, j]
            gu = part.u_loc[i, j][msk] + i * part.block
            gv = part.v_loc[i, j][msk] + j * part.block
            got.append(np.stack([gu, gv], 1))
    got = np.concatenate(got)
    want = np.stack([g.u, g.v], 1)
    got_sorted = got[np.lexsort(got.T[::-1])]
    want_sorted = want[np.lexsort(want.T[::-1])]
    np.testing.assert_array_equal(got_sorted, want_sorted)
