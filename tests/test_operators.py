"""Implicit operators vs dense materialization (paper §5.1.2).

Property-based: on random graphs, every implicit operator must agree
with its explicit dense matrix for matvec, rmatvec and colmax.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property-based tests need the 'test' extra")
from hypothesis import given, settings, strategies as st

from repro.core import (
    AdjacencyPlusId,
    Coo,
    Incidence,
    InterweavedId,
    OnesRow,
    ScaledRows,
    Transposed,
    VertexEdgePair,
    VStack,
)
from repro.graphs import Graph


def random_graph(rng, n, m):
    e = rng.integers(0, n, size=(m, 2))
    g = Graph.from_edges(n, e)
    if g.m == 0:  # ensure at least one edge
        g = Graph.from_edges(n, np.array([[0, 1]]))
    return g


def dense_incidence(g):
    M = np.zeros((g.n, g.m))
    M[g.u, np.arange(g.m)] = 1
    M[g.v, np.arange(g.m)] = 1
    return M


def dense_adj_plus_id(g):
    A = np.eye(g.n)
    A[g.u, g.v] = 1
    A[g.v, g.u] = 1
    return A


def dense_vertex_edge_pair(g):
    O = np.zeros((g.n, 2 * g.m))
    O[g.u, 2 * np.arange(g.m)] = 1
    O[g.v, 2 * np.arange(g.m) + 1] = 1
    return O


def dense_interweaved(g):
    W = np.zeros((g.m, 2 * g.m))
    W[np.arange(g.m), 2 * np.arange(g.m)] = 1
    W[np.arange(g.m), 2 * np.arange(g.m) + 1] = 1
    return W


def check_against_dense(op, D, rng, atol=1e-10):
    m, n = D.shape
    assert op.shape == (m, n)
    x = rng.random(n)
    y = rng.random(m)
    np.testing.assert_allclose(np.asarray(op.matvec(jnp.asarray(x))), D @ x, atol=atol)
    np.testing.assert_allclose(np.asarray(op.rmatvec(jnp.asarray(y))), D.T @ y, atol=atol)
    np.testing.assert_allclose(
        np.asarray(op.colmax()), D.max(axis=0), atol=atol
    )
    s = rng.random(m) + 0.1
    np.testing.assert_allclose(
        np.asarray(op.colmax(jnp.asarray(s))), (D * s[:, None]).max(axis=0), atol=atol
    )


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 30), m=st.integers(1, 80))
def test_incidence_matches_dense(seed, n, m):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, n, m)
    op = Incidence(u=jnp.asarray(g.u), v=jnp.asarray(g.v), n_vertices=g.n)
    check_against_dense(op, dense_incidence(g), rng)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 30), m=st.integers(1, 80))
def test_adj_plus_id_matches_dense(seed, n, m):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, n, m)
    op = AdjacencyPlusId(u=jnp.asarray(g.u), v=jnp.asarray(g.v), n_vertices=g.n)
    D = dense_adj_plus_id(g)
    x = rng.random(g.n)
    np.testing.assert_allclose(np.asarray(op.matvec(jnp.asarray(x))), D @ x, atol=1e-10)
    np.testing.assert_allclose(np.asarray(op.rmatvec(jnp.asarray(x))), D.T @ x, atol=1e-10)
    s = rng.random(g.n) + 0.1
    np.testing.assert_allclose(
        np.asarray(op.colmax(jnp.asarray(s))), (D * s[:, None]).max(axis=0), atol=1e-10
    )


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 30), m=st.integers(1, 80))
def test_vertex_edge_pair_matches_dense(seed, n, m):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, n, m)
    op = VertexEdgePair(u=jnp.asarray(g.u), v=jnp.asarray(g.v), n_vertices=g.n)
    check_against_dense(op, dense_vertex_edge_pair(g), rng)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(1, 40))
def test_interweaved_matches_dense(seed, m):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, max(3, m // 2 + 2), m)
    op = InterweavedId(n_edges=g.m)
    check_against_dense(op, dense_interweaved(g), rng)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_transposed_and_scaled(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, 12, 30)
    M = dense_incidence(g)
    op = Incidence(u=jnp.asarray(g.u), v=jnp.asarray(g.v), n_vertices=g.n)
    check_against_dense(Transposed(op), M.T, rng)
    s = rng.random(g.n) + 0.25
    check_against_dense(ScaledRows(scale=jnp.asarray(s), inner=op), s[:, None] * M, rng)


def test_coo_and_vstack_and_onesrow():
    rng = np.random.default_rng(7)
    D = rng.random((6, 9)) * (rng.random((6, 9)) < 0.4)
    r, c = np.nonzero(D)
    op = Coo(rows=jnp.asarray(r, jnp.int32), cols=jnp.asarray(c, jnp.int32),
             vals=jnp.asarray(D[r, c]), _shape=D.shape)
    check_against_dense(op, D, rng)

    cvec = rng.random(9) + 0.1
    one = OnesRow(c=jnp.asarray(cvec), inv_bound=jnp.asarray(0.25))
    check_against_dense(one, 0.25 * cvec[None, :], rng)

    stk = VStack(ops=(op, one))
    check_against_dense(stk, np.vstack([D, 0.25 * cvec[None, :]]), rng)


def test_coo_padding_entries_are_inert():
    # padded entries: val 0, arbitrary in-range indices
    r = jnp.asarray([0, 1, 0], jnp.int32)
    c = jnp.asarray([0, 1, 0], jnp.int32)
    v = jnp.asarray([2.0, 3.0, 0.0])
    op = Coo(rows=r, cols=c, vals=v, _shape=(2, 2))
    x = jnp.asarray([1.0, 1.0])
    np.testing.assert_allclose(np.asarray(op.matvec(x)), [2.0, 3.0])
    np.testing.assert_allclose(np.asarray(op.rmatvec(x)), [2.0, 3.0])


def test_incidence_edge_mask():
    u = jnp.asarray([0, 1, 0], jnp.int32)
    v = jnp.asarray([1, 2, 2], jnp.int32)
    mask = jnp.asarray([True, True, False])
    op = Incidence(u=u, v=v, n_vertices=3, edge_mask=mask)
    x = jnp.ones(3)
    # masked edge contributes nothing
    np.testing.assert_allclose(np.asarray(op.matvec(x)), [1.0, 2.0, 1.0])
    np.testing.assert_allclose(np.asarray(op.rmatvec(jnp.asarray([1.0, 2.0, 4.0]))),
                               [3.0, 6.0, 0.0])


def test_materialize_roundtrip(small_graphs):
    g = small_graphs["triangle"]
    op = Incidence(u=jnp.asarray(g.u), v=jnp.asarray(g.v), n_vertices=g.n)
    np.testing.assert_allclose(np.asarray(op.materialize()), dense_incidence(g))
