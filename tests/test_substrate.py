"""Training/serving substrate: optimizer, train loop convergence,
checkpoint save/restore/resume, data pipeline determinism, MoE smoke,
per-arch reduced-config train_step (shapes + no-NaN + loss decreases)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import latest_step, restore_latest, save, save_async, wait_pending
from repro.configs import ARCH_IDS, get
from repro.data.synthetic import TokenPipeline
from repro.models import Model
from repro.train.optimizer import AdamWConfig, lr_at
from repro.train.step import chunked_ce_loss, make_train_state, make_train_step


def tiny_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.modality == "audio_frames":
        batch["frames"] = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)) * 0.1, jnp.float32)
    elif cfg.modality == "vision_text":
        npt = cfg.n_vision_patches
        batch["patches"] = jnp.asarray(rng.standard_normal((B, npt, cfg.d_model)) * 0.1, jnp.float32)
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - npt)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch["targets"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch["loss_mask"] = jnp.ones((B, S), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step_all_archs(arch):
    """Deliverable (f): per-arch smoke — one train step, shapes, no NaN."""
    cfg = get(arch).reduced()
    model = Model(cfg, fsdp=False)
    state = make_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)))
    batch = tiny_batch(cfg)
    state2, metrics = step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state2.step) == 1
    # params actually changed
    d0 = jax.tree.leaves(state.params)[0]
    d1 = jax.tree.leaves(state2.params)[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


def test_loss_decreases_small_lm():
    """A few hundred params of signal: loss must go down over steps."""
    cfg = get("minitron-4b").reduced()
    model = Model(cfg, fsdp=False)
    state = make_train_state(model, jax.random.PRNGKey(0))
    pipe = TokenPipeline(cfg.vocab_size, seq_len=64, global_batch=8, seed=0)
    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)))
    losses = []
    for it in range(30):
        b = {k: jnp.asarray(v) for k, v in pipe.batch_at(it).items()}
        state, m = step_fn(state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[:3] + losses[-3:]


def test_microbatch_accumulation_matches_full():
    cfg = get("minitron-4b").reduced()
    model = Model(cfg, fsdp=False)
    state = make_train_state(model, jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, B=4)
    s1, m1 = jax.jit(make_train_step(model, AdamWConfig()))(state, batch)
    s2, m2 = jax.jit(make_train_step(model, AdamWConfig(), microbatches=2))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_chunked_loss_matches_unchunked():
    cfg = get("yi-34b").reduced()
    model = Model(cfg, fsdp=False)
    params = model.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, B=2, S=40)
    l1 = chunked_ce_loss(model, params, batch, chunk=7)
    l2 = chunked_ce_loss(model, params, batch, chunk=40)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_optimizer_lr_schedule():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_at(cfg, 0)) < 2e-4
    assert abs(float(lr_at(cfg, 10)) - 1e-3) < 1.2e-4
    assert float(lr_at(cfg, 99)) <= 1.2e-4 + 1e-9


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(5, dtype=jnp.float32), "b": {"c": jnp.ones((2, 3))}}
    save(tmp_path, 3, tree)
    save(tmp_path, 7, jax.tree.map(lambda x: x * 2, tree))
    assert latest_step(tmp_path) == 7
    restored, step = restore_latest(tmp_path, tree)
    assert step == 7
    np.testing.assert_allclose(np.asarray(restored["a"]), np.arange(5) * 2)


def test_checkpoint_async_and_gc(tmp_path):
    tree = {"w": jnp.zeros((4,))}
    for s in range(5):
        save_async(tmp_path, s, tree, keep=2)
    wait_pending()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_checkpoint_ignores_partial(tmp_path):
    tree = {"w": jnp.zeros((4,))}
    save(tmp_path, 1, tree)
    # simulate a torn write: directory without manifest
    (tmp_path / "step_9").mkdir()
    assert latest_step(tmp_path) == 1


def test_data_pipeline_deterministic_seekable():
    p1 = TokenPipeline(1000, 32, 4, seed=5)
    p2 = TokenPipeline(1000, 32, 4, seed=5)
    b_a = p1.batch_at(17)
    b_b = p2.batch_at(17)  # fresh object, same (seed, step)
    np.testing.assert_array_equal(b_a["tokens"], b_b["tokens"])
    b_c = p1.batch_at(18)
    assert not np.array_equal(b_a["tokens"], b_c["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(b_a["targets"][:, :-1], b_a["tokens"][:, 1:])


def test_trainer_restart_resumes(tmp_path):
    """Fault-tolerance: kill-and-restart reproduces the uninterrupted run."""
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get("minitron-4b").reduced()
    tc = TrainerConfig(steps=8, ckpt_every=2, seq_len=32, global_batch=4,
                      ckpt_dir=str(tmp_path / "ck"), log_every=100)
    t1 = Trainer(cfg, tc)
    t1.run()  # full run
    ref_loss = t1.last_metrics["loss"]

    # interrupted run: 5 steps, then a fresh Trainer resumes from ckpt
    tc2 = TrainerConfig(steps=5, ckpt_every=2, seq_len=32, global_batch=4,
                       ckpt_dir=str(tmp_path / "ck2"), log_every=100)
    ta = Trainer(cfg, tc2)
    ta.run()
    tc3 = TrainerConfig(steps=8, ckpt_every=2, seq_len=32, global_batch=4,
                       ckpt_dir=str(tmp_path / "ck2"), log_every=100)
    tb = Trainer(cfg, tc3)
    tb.run()  # resumes at step 4 (last ckpt) and finishes
    assert abs(tb.last_metrics["loss"] - ref_loss) < 1e-4
