"""MWU solver correctness: scipy-HiGHS oracle + infeasibility + invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property-based tests need the 'test' extra")
from hypothesis import given, settings, strategies as st
from scipy.optimize import linprog

from repro.core import Dense, MWUOptions, Status, solve, solve_traced
from repro.core.mwu import init_x, make_eta
from repro.core.smoothing import smax, smin, smax_weights, smin_weights


def random_mixed_lp(rng, mp, mc, n, density=0.5):
    P = rng.random((mp, n)) * (rng.random((mp, n)) < density)
    C = rng.random((mc, n)) * (rng.random((mc, n)) < density)
    # every column in P, every row of C nonempty (well-posedness)
    P[rng.integers(0, mp), :] += 0.05
    C[:, rng.integers(0, n)] += 0.05
    return P, C


def scipy_feasible(P, C):
    r = linprog(
        c=np.zeros(P.shape[1]),
        A_ub=np.vstack([P, -C]),
        b_ub=np.concatenate([np.ones(P.shape[0]), -np.ones(C.shape[0])]),
        bounds=(0, None),
        method="highs",
    )
    return r.success


@pytest.mark.parametrize("rule", ["std", "binary", "newton"])
def test_simple_feasible(rule):
    # x <= 1 each; x1 + x2 >= 1 — trivially feasible
    P = Dense(mat=jnp.eye(2))
    C = Dense(mat=jnp.array([[0.9, 0.9]]))
    opts = MWUOptions(eps=0.1, step_rule=rule, max_iter=20000)
    res = solve(P, C, opts)
    assert int(res.status) == Status.FEASIBLE
    assert float(res.max_px) <= 1.1 + 1e-6
    assert float(res.min_cx) >= 1.0


@pytest.mark.parametrize("rule", ["binary", "newton"])
def test_simple_infeasible(rule):
    P = Dense(mat=jnp.eye(2))
    C = Dense(mat=jnp.array([[1.0, 1.0]]) / 3.0)
    res = solve(P, C, MWUOptions(eps=0.1, step_rule=rule))
    assert int(res.status) == Status.INFEASIBLE


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_matches_scipy_feasibility(seed):
    rng = np.random.default_rng(seed)
    P, C = random_mixed_lp(rng, 8, 6, 12)
    feas = scipy_feasible(P, C)
    res = solve(
        Dense(mat=jnp.asarray(P)),
        Dense(mat=jnp.asarray(C)),
        MWUOptions(eps=0.1, step_rule="newton", max_iter=30000),
    )
    st_ = int(res.status)
    if feas:
        assert st_ == Status.FEASIBLE, f"scipy feasible, mwu {Status.NAMES[st_]}"
        # returned x certifies (1+eps) feasibility
        x = np.asarray(res.x)
        assert (P @ x <= 1.1 + 1e-6).all()
        assert (C @ x >= 1.0 - 1e-9).all()
    elif st_ == Status.FEASIBLE:
        # MWU answers the (1+eps)-RELAXED problem: an exactly-infeasible LP
        # may legitimately be (1+eps)-feasible (hypothesis found seed 1014).
        # The claim is only valid if the relaxed certificate holds AND the
        # relaxed LP is indeed feasible per the exact solver.
        x = np.asarray(res.x)
        assert (P @ x <= 1.1 + 1e-6).all()
        assert (C @ x >= 1.0 - 1e-9).all()
        assert scipy_feasible(P / 1.1, C), "relaxed LP must be exactly feasible"
    else:
        assert st_ in (Status.INFEASIBLE, Status.ITER_LIMIT)


def test_solution_certificate_feasible_region():
    rng = np.random.default_rng(42)
    for _ in range(3):
        P, C = random_mixed_lp(rng, 10, 5, 15)
        if not scipy_feasible(P, C):
            continue
        res = solve(
            Dense(mat=jnp.asarray(P)),
            Dense(mat=jnp.asarray(C)),
            MWUOptions(eps=0.05, step_rule="binary", max_iter=50000),
        )
        assert int(res.status) == Status.FEASIBLE
        x = np.asarray(res.x)
        assert (x >= 0).all()
        assert (P @ x).max() <= 1.05 + 1e-6


def test_traced_matches_jit():
    rng = np.random.default_rng(3)
    P, C = random_mixed_lp(rng, 8, 6, 12)
    opts = MWUOptions(eps=0.1, step_rule="newton", max_iter=30000)
    r1 = solve(Dense(mat=jnp.asarray(P)), Dense(mat=jnp.asarray(C)), opts)
    r2, trace = solve_traced(Dense(mat=jnp.asarray(P)), Dense(mat=jnp.asarray(C)), opts)
    assert int(r1.status) == int(r2.status)
    assert abs(int(r1.iters) - int(r2.iters)) <= 1
    if int(r1.status) == Status.FEASIBLE:
        assert trace["max_violation"][-1] <= 0.1 + 1e-9


def test_x_monotone_nondecreasing():
    """MWU only ever adds nonnegative multiples of x (multiplicative update)."""
    rng = np.random.default_rng(5)
    P, C = random_mixed_lp(rng, 6, 4, 8)
    if not scipy_feasible(P, C):
        pytest.skip("draw infeasible")
    Pd, Cd = Dense(mat=jnp.asarray(P)), Dense(mat=jnp.asarray(C))
    opts = MWUOptions(eps=0.1, step_rule="binary", max_iter=5000)
    x0 = np.asarray(init_x(Pd, 0.1, jnp.float64))
    res = solve(Pd, Cd, opts)
    assert (np.asarray(res.x) >= x0 - 1e-15).all()


def test_smoothing_bounds():
    """smax in [max, max + log(m)/eta]; smin in [min - log(m)/eta, min]."""
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.random(100))
    eta = make_eta(100, 0.1)
    assert float(smax(v, eta)) >= float(v.max())
    assert float(smax(v, eta)) <= float(v.max()) + np.log(100) / eta + 1e-12
    assert float(smin(v, eta)) <= float(v.min())
    assert float(smin(v, eta)) >= float(v.min()) - np.log(100) / eta - 1e-12
    # gradients are probability vectors
    np.testing.assert_allclose(float(smax_weights(v, eta).sum()), 1.0, rtol=1e-10)
    np.testing.assert_allclose(float(smin_weights(v, eta).sum()), 1.0, rtol=1e-10)


def test_smoothing_no_overflow_large_eta():
    v = jnp.asarray([1e3, 0.0, -1e3])
    eta = 1e4
    assert np.isfinite(float(smax(v, eta)))
    assert np.isfinite(float(smin(v, eta)))
    w = smax_weights(v, eta)
    assert np.isfinite(np.asarray(w)).all()


def test_masked_covering_rows():
    """Masked covering rows must not influence the solve."""
    P = Dense(mat=jnp.eye(2))
    # second covering row is absurd (x1+x2 >= 10) but masked out
    C = Dense(mat=jnp.array([[0.9, 0.9], [10.0, 10.0]]))
    mask = jnp.asarray([True, False])
    res = solve(P, C, MWUOptions(eps=0.1, step_rule="newton"), c_mask=mask)
    assert int(res.status) == Status.FEASIBLE
