"""Batched serving engine: prefill + decode with slot-based batching.

A fixed number of batch slots share one jitted decode_step; requests are
prefetched into free slots (continuous batching, vLLM-style but
slot-static for XLA shape stability). Sampling: greedy or temperature.
Caches: full KV / ring (SWA) / SSM state — whatever the arch dictates
(Model.init_caches). This is the serving driver behind examples/serve_lm.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models import Model

__all__ = ["ServeConfig", "Engine"]


@dataclass
class ServeConfig:
    max_len: int = 512
    slots: int = 4  # concurrent sequences
    temperature: float = 0.0  # 0 = greedy
    seed: int = 0


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: list = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg, sc: ServeConfig):
        self.cfg = cfg
        self.sc = sc
        self.model = Model(cfg, fsdp=False)
        self.params = None
        self._decode = jax.jit(self.model.decode_step)
        self._rng = jax.random.PRNGKey(sc.seed)

    def load(self, params):
        self.params = params

    def _sample(self, logits):
        if self.sc.temperature <= 0:
            return jnp.argmax(logits[:, -1, : self.cfg.vocab_size], axis=-1)
        self._rng, k = jax.random.split(self._rng)
        return jax.random.categorical(
            k, logits[:, -1, : self.cfg.vocab_size] / self.sc.temperature, axis=-1
        )

    def generate(self, prompts: list[np.ndarray], max_new: int = 32) -> list[list[int]]:
        """Slot-batched generation; prompts shorter than the longest are
        left-padded into their own slot via separate prefill."""
        sc = self.sc
        reqs = [Request(i, np.asarray(p, np.int32), max_new) for i, p in enumerate(prompts)]
        outs: dict[int, list[int]] = {r.rid: [] for r in reqs}
        queue = list(reqs)

        while queue:
            active = queue[: sc.slots]
            queue = queue[sc.slots :]
            B = len(active)
            # per-slot prefill: equalize prompt lengths by batching equal
            # lengths; here simply decode prompt tokens sequentially after
            # a one-token prime (keeps shapes static for any mix).
            caches = self.model.init_caches(B, sc.max_len)
            maxp = max(len(r.prompt) for r in active)
            toks = np.zeros((B, maxp), np.int32)
            lens = np.array([len(r.prompt) for r in active])
            for i, r in enumerate(active):
                toks[i, : lens[i]] = r.prompt
            # teacher-forced pass over the prompt region
            last = None
            for t in range(maxp):
                logits, caches = self._decode(self.params, caches, jnp.asarray(toks[:, t : t + 1]))
                last = logits
            cur = np.asarray(self._sample(last))
            for i, r in enumerate(active):
                outs[r.rid].append(int(cur[i]))
            for _ in range(max_new - 1):
                logits, caches = self._decode(self.params, caches, jnp.asarray(cur[:, None]))
                cur = np.asarray(self._sample(logits))
                for i, r in enumerate(active):
                    outs[r.rid].append(int(cur[i]))
        return [outs[r.rid] for r in reqs]
