"""Shared model-plane utilities: init, dtype policy, sharding annotations.

Parameters are plain nested dicts of jax arrays (no framework dependency).
Each init function has a twin ``*_spec`` producing a matching pytree of
``PartitionSpec``s; ``shard_params_tree`` zips them into NamedShardings.

Sharding vocabulary (DESIGN.md §5):
  DP axes = ("pod", "data") when present — batch & ZeRO/FSDP shards.
  TP axis = "model"          — Megatron-style tensor parallel dims.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Dtypes",
    "dense_init",
    "truncated_normal_init",
    "with_sharding",
    "dp_axes",
    "DP",
    "TP",
]

TP = "model"


def dp_axes(mesh_axes) -> tuple:
    """The data-parallel axes present in this mesh ('pod' absorbs into DP)."""
    return tuple(a for a in ("pod", "data") if a in mesh_axes)


def DP(mesh_axes) -> Any:
    axes = dp_axes(mesh_axes)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


class Dtypes:
    """Resolved dtype policy for a config."""

    def __init__(self, cfg):
        self.param = jnp.dtype(cfg.param_dtype)
        self.compute = jnp.dtype(cfg.dtype)
        self.logit = jnp.dtype(cfg.logit_dtype)

    def cast(self, x):
        return x.astype(self.compute)


def truncated_normal_init(key, shape, dtype, scale):
    """He/LeCun-style truncated normal (stddev = scale / sqrt(fan_in))."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def dense_init(key, shape, dtype, scale=1.0):
    return truncated_normal_init(key, shape, dtype, scale)


def with_sharding(x, spec, mesh=None):
    """Annotate intermediate sharding (no-op outside jit/mesh contexts)."""
    try:
        if mesh is not None:
            return jax.lax.with_sharding_constraint(
                x, jax.sharding.NamedSharding(mesh, spec)
            )
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # no mesh context (single-device smoke tests)
