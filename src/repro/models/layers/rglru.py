"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrent block:  out = W_out( GeLU(W_gate u) ⊙ RGLRU(conv1d(W_x u)) )
RG-LRU cell:      r_t = sigmoid(W_a xi_t);  i_t = sigmoid(W_i xi_t)
                  log a_t = -c * softplus(Lambda) * r_t          (c = 8)
                  h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ xi_t)

The linear recurrence is evaluated with ``jax.lax.associative_scan``
(log-depth — the TPU-native answer to the paper-family's sequential
scan kernels). Decode carries (conv window, h) — O(1) per token, making
the long_500k cell meaningful (DESIGN.md §6).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..common import DP, TP, dense_init, with_sharding

__all__ = ["rglru_init", "rglru_spec", "rglru_apply", "rglru_decode", "RGLRUState", "init_rglru_state"]

_C = 8.0
_CONV_K = 4


class RGLRUState(NamedTuple):
    conv: jax.Array  # (B, K-1, w)
    h: jax.Array  # (B, w) recurrent state
    pos: jax.Array  # ()


def init_rglru_state(cfg, batch, dtype=jnp.float32):
    w = cfg.rnn_width
    return RGLRUState(
        conv=jnp.zeros((batch, _CONV_K - 1, w), dtype),
        h=jnp.zeros((batch, w), jnp.float32),
        pos=jnp.zeros((), jnp.int32),
    )


def rglru_init(key, cfg, dtype):
    d, w = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(key, 5)
    return {
        "wx": dense_init(ks[0], (d, w), dtype),
        "wgate": dense_init(ks[1], (d, w), dtype),
        "conv_w": dense_init(ks[2], (_CONV_K, w), dtype, scale=1.0),
        "conv_b": jnp.zeros((w,), dtype),
        # diagonal recurrence/input gates (RecurrentGemma uses block-diag;
        # diagonal is the faithful-lite variant, noted in DESIGN.md)
        "wa": dense_init(ks[3], (w,), jnp.float32, scale=1.0),
        "wi": dense_init(ks[4], (w,), jnp.float32, scale=1.0),
        "lam": jnp.linspace(0.9, 0.999, w).astype(jnp.float32),  # a ~ in (0.9, 0.999)
        "wout": dense_init(jax.random.fold_in(key, 9), (w, d), dtype,
                           scale=1.0 / np.sqrt(2 * cfg.n_layers)),
    }


def rglru_spec(cfg, fsdp: bool):
    dp = "data" if fsdp else None
    return {
        "wx": P(dp, TP),
        "wgate": P(dp, TP),
        "conv_w": P(None, TP),
        "conv_b": P(TP),
        "wa": P(TP),
        "wi": P(TP),
        "lam": P(TP),
        "wout": P(TP, dp),
    }


def _gates(params, xi):
    """r, i, log_a, beta from the conv output xi (f32)."""
    xf = xi.astype(jnp.float32)
    r = jax.nn.sigmoid(xf * params["wa"])
    i = jax.nn.sigmoid(xf * params["wi"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * (i * xf)


def _conv(params, x, window):
    K = _CONV_K
    if window is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = window.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    w = params["conv_w"].astype(x.dtype)
    out = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(K))
    return out + params["conv_b"].astype(x.dtype), xp[:, -(K - 1) :]


def rglru_apply(params, xin, cfg, mesh_axes=("data", "model"), state: RGLRUState | None = None):
    """Full-sequence recurrent block. Returns (out, new_state|None)."""
    dp = DP(mesh_axes)
    B, S, d = xin.shape
    xb = xin @ params["wx"].astype(xin.dtype)
    gate = jax.nn.gelu(xin @ params["wgate"].astype(xin.dtype))
    xi, conv_win = _conv(params, xb, None if state is None else state.conv)
    xi = with_sharding(xi, P(dp, None, TP))

    a, b = _gates(params, xi)  # (B,S,w) f32
    if state is not None:
        # fold carried state into the first step: h_0 contribution
        b = b.at[:, 0].add(a[:, 0] * state.h)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(xin.dtype)) * gate
    out = y @ params["wout"].astype(xin.dtype)
    new_state = None
    if state is not None:
        new_state = RGLRUState(conv=conv_win, h=h[:, -1], pos=state.pos + S)
    return with_sharding(out, P(dp, None, None)), new_state


def rglru_decode(params, xin, cfg, state: RGLRUState, mesh_axes=("data", "model")):
    """Single-token step. xin: (B,1,d)."""
    x1 = xin[:, 0]
    xb = x1 @ params["wx"].astype(xin.dtype)
    gate = jax.nn.gelu(x1 @ params["wgate"].astype(xin.dtype))
    win = jnp.concatenate([state.conv.astype(xin.dtype), xb[:, None]], axis=1)
    w = params["conv_w"].astype(xin.dtype)
    xi = (win * w[None]).sum(axis=1) + params["conv_b"].astype(xin.dtype)
    a, b = _gates(params, xi[:, None, :])
    a, b = a[:, 0], b[:, 0]
    h = a * state.h + b
    y = h.astype(xin.dtype) * gate
    out = (y @ params["wout"].astype(xin.dtype))[:, None]
    return out, RGLRUState(conv=win[:, 1:], h=h, pos=state.pos + 1)
