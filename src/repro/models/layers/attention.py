"""GQA attention: train/prefill (dense | chunked-flash) + decode w/ caches.

Supports every attention variant in the assigned pool:
  * GQA with arbitrary (n_heads, n_kv_heads) — yi/starcoder2/minitron/dbrx/
    mixtral/internvl2; MQA (kv=1) — recurrentgemma; MHA — qwen/hubert.
  * QKV bias (qwen1.5), RoPE (all decoders), bidirectional (hubert).
  * Sliding-window attention (mixtral SWA, recurrentgemma local attn) with
    ring-buffer KV caches for O(window) decode memory.

Implementations:
  * ``dense``   — materializes scores; smoke tests and decode.
  * ``chunked`` — flash-style running-LSE streaming over KV chunks with
    q-blocking: the XLA twin of kernels/flash_attention (same math, same
    FLOP count); this is what the multi-pod dry-run lowers, since Mosaic
    kernels cannot lower on CPU backends (DESIGN.md §4).
  * ``pallas``  — the Pallas kernel (TPU target; interpret-mode on CPU).

Sharding: activations are annotated (DP, None, TP, None) on the head
axis; decode KV caches are sharded (DP, TP-on-seq) so a 32k cache fits
a v5e (DESIGN.md §5). GSPMD inserts the LSE/psum combines for softmax
over the sharded seq axis (flash-decoding pattern).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..common import DP, TP, dense_init, with_sharding
from .rope import apply_rope

__all__ = ["attention_init", "attention_spec", "attention_apply", "KVCache", "init_kv_cache"]

_NEG_INF = -1e30


class KVCache(NamedTuple):
    """Single-layer KV cache. ``window`` caches are rings (SWA)."""

    k: jax.Array  # (B, S_cache, Hkv, dh) — rope already applied
    v: jax.Array  # (B, S_cache, Hkv, dh)
    slot_pos: jax.Array  # (S_cache,) absolute position per slot, -1 = empty


def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, prefix=None):
    """Empty cache; for SWA archs max_len is min(window, max_len)."""
    if cfg.sliding_window is not None:
        max_len = min(max_len, cfg.sliding_window)
    shape = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return KVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        slot_pos=jnp.full((max_len,), -1, jnp.int32),
    )


def attention_init(key, cfg, dtype):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, qd), dtype),
        "wk": dense_init(ks[1], (d, kvd), dtype),
        "wv": dense_init(ks[2], (d, kvd), dtype),
        "wo": dense_init(ks[3], (qd, d), dtype, scale=1.0 / np.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), dtype)
        p["bk"] = jnp.zeros((kvd,), dtype)
        p["bv"] = jnp.zeros((kvd,), dtype)
    return p


def attention_spec(cfg, fsdp: bool):
    """PartitionSpecs; fsdp additionally shards the non-TP dim over data."""
    dp = "data" if fsdp else None
    s = {
        "wq": P(dp, TP),
        "wk": P(dp, TP),
        "wv": P(dp, TP),
        "wo": P(TP, dp),
    }
    if cfg.qkv_bias:
        s.update({"bq": P(TP), "bk": P(TP), "bv": P(TP)})
    return s


def _mask_bias(q_pos, k_pos, *, causal, window, dtype):
    """(..., Sq, Sk) additive mask from absolute positions."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = kp >= 0  # valid slot
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    return jnp.where(ok, 0.0, _NEG_INF).astype(dtype)


def _sdpa_dense(q, k, v, q_pos, k_pos, *, causal, window):
    """q: (B,Sq,Hq,dh); k/v: (B,Sk,Hkv,dh) -> (B,Sq,Hq,dh). f32 softmax."""
    B, Sq, Hq, dh = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, g, dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores * (1.0 / float(np.sqrt(dh)))  # python float: no x64 promotion
    mask = _mask_bias(q_pos, k_pos, causal=causal, window=window, dtype=jnp.float32)
    if mask.ndim == 3:  # (B, Sq, Sk) -> broadcast over (Hkv, g)
        mask = mask[:, None, None, :, :]
    scores = scores + mask
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, dh)


def _sdpa_chunked(q, k, v, q_pos, k_pos, *, causal, window, q_block, kv_block):
    """Flash-style streaming attention (running max / sum / accumulator).

    Outer: q blocks (lax.map). Inner: scan over kv chunks. Per-step
    footprint is (B, qb, Hq, cb) — independent of total sequence length.
    """
    B, Sq, Hq, dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qb = min(q_block, Sq)
    cb = min(kv_block, Sk)
    n_qb = (Sq + qb - 1) // qb
    n_kb = (Sk + cb - 1) // cb
    # pad to block multiples
    q = jnp.pad(q, ((0, 0), (0, n_qb * qb - Sq), (0, 0), (0, 0)))
    qp = jnp.pad(q_pos, ((0, n_qb * qb - Sq),), constant_values=2**30)
    k = jnp.pad(k, ((0, 0), (0, n_kb * cb - Sk), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, n_kb * cb - Sk), (0, 0), (0, 0)))
    kp = jnp.pad(k_pos, ((0, n_kb * cb - Sk),), constant_values=-1)

    kc = k.reshape(B, n_kb, cb, Hkv, dh)
    vc = v.reshape(B, n_kb, cb, Hkv, dh)
    kpc = kp.reshape(n_kb, cb)

    def q_block_fn(args):
        qi, qpi = args  # (B, qb, Hq, dh), (qb,)
        qg = qi.reshape(B, qb, Hkv, g, dh)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, vj, kpj = inp  # (B, cb, Hkv, dh), (cb,)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kj).astype(jnp.float32) * (
                1.0 / float(np.sqrt(dh))
            )
            s = s + _mask_bias(qpi, kpj, causal=causal, window=window, dtype=jnp.float32)
            m2 = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = l * corr + p.sum(axis=-1)
            acc2 = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vj.dtype), vj
            ).astype(jnp.float32)
            return (m2, l2, acc2), None

        m0 = jnp.full((B, Hkv, g, qb), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, qb, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), kpc),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, qb, Hq, dh)

    qblocks = jnp.moveaxis(q.reshape(B, n_qb, qb, Hq, dh), 1, 0)
    qpb = qp.reshape(n_qb, qb)
    out = jax.lax.map(q_block_fn, (qblocks, qpb))  # (n_qb, B, qb, Hq, dh)
    out = jnp.moveaxis(out, 0, 1).reshape(B, n_qb * qb, Hq, dh)
    return out[:, :Sq].astype(q.dtype)


def attention_apply(
    params,
    x,
    cfg,
    *,
    positions,  # (S,) or (B,S) absolute positions of x tokens
    cache: Optional[KVCache] = None,
    mesh_axes=("data", "model"),
    impl: Optional[str] = None,
):
    """Returns (out (B,S,d), new_cache).

    cache=None      : train/prefill without cache materialization.
    cache=KVCache   : appends x's K/V at ``positions`` then attends over
                      the cache (decode: S == 1; chunked prefill: S > 1).
    """
    B, S, d = x.shape
    dp = DP(mesh_axes)
    impl = impl or cfg.attn_impl

    # preferred_element_type pins the dot output (and thus any GSPMD
    # partial-sum all-reduce) to the compute dtype — bf16 collectives
    # instead of f32 (EXPERIMENTS.md §Perf, yi-34b hillclimb).
    q = jnp.matmul(x, params["wq"].astype(x.dtype), preferred_element_type=x.dtype)
    k = jnp.matmul(x, params["wk"].astype(x.dtype), preferred_element_type=x.dtype)
    v = jnp.matmul(x, params["wv"].astype(x.dtype), preferred_element_type=x.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.n_heads, cfg.d_head)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    q = with_sharding(q, P(dp, None, TP, None))
    # GQA K/V: n_kv_heads (8 / 4 / 1) rarely divides a 16-way TP axis;
    # padded-uneven sharding makes GSPMD re-gather K/V around every
    # attention scan step (~2 TB/step at the yi train cell). K/V are
    # small under GQA, so replicate them across TP: one gather after the
    # projection instead (EXPERIMENTS.md §Perf, yi-34b iteration 2).
    kv_even = cfg.n_kv_heads % 16 == 0
    kv_spec = P(dp, None, TP, None) if kv_even else P(dp, None, None, None)
    k = with_sharding(k, kv_spec)
    v = with_sharding(v, kv_spec)

    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None, :], (B, S))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        W = cache.k.shape[1]
        pos0 = positions[0]  # slot logic is batch-uniform; (S,)
        slot = jnp.mod(pos0, W) if cfg.sliding_window is not None else pos0
        # scatter new K/V into cache slots (advanced index on the seq axis)
        ck = cache.k.at[:, slot].set(k.astype(cache.k.dtype))
        cv = cache.v.at[:, slot].set(v.astype(cache.v.dtype))
        spos = cache.slot_pos.at[slot].set(pos0)
        new_cache = KVCache(k=ck, v=cv, slot_pos=spos)
        if S > 1:
            # prefill from an empty cache: attention is over the prompt
            # itself — use the memory-efficient streaming path on the
            # local K/V rather than dense scores over the whole cache.
            out = _sdpa_chunked(
                q, k, v, positions[0], positions[0],
                causal=cfg.causal, window=cfg.sliding_window,
                q_block=cfg.attn_chunk, kv_block=cfg.attn_chunk,
            )
        else:
            # decode: q replicated over TP; cache stays seq-sharded and
            # GSPMD emits the flash-decoding LSE combine over shards.
            q = with_sharding(q, P(dp, None, None, None))
            out = _sdpa_dense(
                q,
                ck.astype(q.dtype),
                cv.astype(q.dtype),
                positions,
                spos,
                causal=cfg.causal,
                window=cfg.sliding_window,
            )
    else:
        k_pos = positions[0]
        if impl == "dense" or S <= cfg.attn_chunk:
            out = _sdpa_dense(q, k, v, positions, k_pos, causal=cfg.causal, window=cfg.sliding_window)
        elif impl == "pallas":
            from ...kernels.flash_attention import ops as fa_ops

            out = fa_ops.flash_attention(
                q, k, v, positions[0], causal=cfg.causal, window=cfg.sliding_window,
                block_q=min(cfg.attn_chunk, S), block_k=min(cfg.attn_chunk, S),
            )
        else:
            out = _sdpa_chunked(
                q, k, v, positions[0], k_pos,
                causal=cfg.causal, window=cfg.sliding_window,
                q_block=cfg.attn_chunk, kv_block=cfg.attn_chunk,
            )

    out = with_sharding(out, P(dp, None, TP, None))
    out = jnp.matmul(
        out.reshape(B, S, cfg.q_dim), params["wo"].astype(x.dtype),
        preferred_element_type=x.dtype,
    )
    return with_sharding(out, P(dp, None, None)), new_cache
