"""Rotary position embeddings (RoPE, arXiv:2104.09864) + sinusoidal abs."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["apply_rope", "sinusoidal_positions"]


def _rope_angles(positions, d_head: int, theta: float):
    """(..., S) int positions -> cos/sin tables (..., S, d_head/2)."""
    half = d_head // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (B, S, H, D) -> rotated; positions: (B, S) or (S,)."""
    if positions.ndim == 1:
        positions = positions[None, :]
    cos, sin = _rope_angles(positions, x.shape[-1], theta)  # (B,S,half)
    cos = cos[:, :, None, :]  # broadcast over heads
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(S: int, d: int, dtype=jnp.float32):
    """Classic transformer sin/cos absolute position table (S, d)."""
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((S, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div[: (d + 1) // 2][: pe[:, 1::2].shape[-1]]))
    return pe.astype(dtype)
