"""RMSNorm / LayerNorm (computed in f32, cast back to compute dtype)."""
from __future__ import annotations

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["norm_init", "norm_spec", "apply_norm"]


def norm_init(d: int, norm_type: str, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_spec(norm_type: str):
    s = {"scale": P(None)}
    if norm_type == "layernorm":
        s["bias"] = P(None)
    return s


def apply_norm(params, x, norm_type: str, eps: float):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) / jnp.sqrt(var + eps)
        out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * (1.0 / jnp.sqrt(ms + eps)) * params["scale"].astype(jnp.float32)
    return out.astype(dt)
