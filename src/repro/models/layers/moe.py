"""Mixture-of-Experts layer (dbrx 16e/top-4, mixtral 8e/top-2).

Dispatch is sort-based (capacity-bounded gather/scatter, MegaBlocks-style
rather than GShard one-hot einsums, whose (tokens x experts x capacity)
dispatch tensors cannot fit at 1M-token dry-run shapes).

Routers:
  * ``topk`` — standard softmax top-k with capacity dropping.
  * ``mwu``  — **the paper's technique as a first-class feature**: the
    token->expert assignment is a mixed packing/covering LP

        max <affinity, x>   s.t.  sum_t x[t,e] <= capacity_e   (packing)
                                  sum_e x[t,e] >= top_k        (covering)
                                  0 <= x[t,e] <= 1             (packing)

    solved in-graph by ``repro.core.solve`` (Algorithm 2, Newton line
    search) over implicit row/column-sum operators — exactly the solver
    used for the graph LPs, running inside the model's forward pass. The
    fractional assignment is rounded per-token to top-k; capacities are
    respected in expectation, which measurably flattens expert load
    (see tests/test_moe.py and examples/moe_mwu_routing.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ...core import MWUOptions, OnesRow, VStack, solve
from ...core.operators import LinOp, register_op, static_field
from ..common import DP, TP, dense_init, with_sharding

__all__ = ["moe_init", "moe_spec", "moe_apply", "mwu_route", "topk_route", "expert_load"]


# ----------------------------------------------------------------------
# Implicit operators for the routing LP (T tokens x E experts variables)
# ----------------------------------------------------------------------


@register_op
@dataclass
class ExpertCapRows(LinOp):
    """Packing rows: (sum_t x[t,e]) / cap_e <= 1. Shape (E, T*E)."""

    inv_cap: jax.Array  # (E,)
    T: int = static_field(default=0)

    @property
    def shape(self):
        E = int(self.inv_cap.shape[0])
        return (E, self.T * E)

    def matvec(self, x):
        E = self.inv_cap.shape[0]
        return x.reshape(self.T, E).sum(axis=0) * self.inv_cap

    def rmatvec(self, w):
        E = self.inv_cap.shape[0]
        return jnp.broadcast_to((w * self.inv_cap)[None, :], (self.T, E)).reshape(-1)

    def colmax(self, row_scale=None):
        E = self.inv_cap.shape[0]
        s = self.inv_cap if row_scale is None else self.inv_cap * row_scale
        return jnp.broadcast_to(s[None, :], (self.T, E)).reshape(-1)

    @property
    def nnz(self):
        return self.T * int(self.inv_cap.shape[0])


@register_op
@dataclass
class TokenSumRows(LinOp):
    """Covering rows: (sum_e x[t,e]) / k >= 1. Shape (T, T*E)."""

    inv_k: jax.Array  # scalar
    T: int = static_field(default=0)
    E: int = static_field(default=0)

    @property
    def shape(self):
        return (self.T, self.T * self.E)

    def matvec(self, x):
        return x.reshape(self.T, self.E).sum(axis=1) * self.inv_k

    def rmatvec(self, w):
        return jnp.broadcast_to((w * self.inv_k)[:, None], (self.T, self.E)).reshape(-1)

    def colmax(self, row_scale=None):
        if row_scale is None:
            return jnp.broadcast_to(self.inv_k, (self.T * self.E,))
        return self.rmatvec(row_scale)

    @property
    def nnz(self):
        return self.T * self.E


@register_op
@dataclass
class BoxRows(LinOp):
    """Packing rows x[t,e] <= 1 (identity)."""

    n: int = static_field(default=0)

    @property
    def shape(self):
        return (self.n, self.n)

    def matvec(self, x):
        return x

    def rmatvec(self, y):
        return y

    def colmax(self, row_scale=None):
        if row_scale is None:
            return jnp.ones((self.n,), jnp.float32)
        return row_scale

    @property
    def nnz(self):
        return self.n


# ----------------------------------------------------------------------
# Routers
# ----------------------------------------------------------------------


def topk_route(logits, k):
    """(T, E) logits -> (expert_idx (T,k), gate (T,k)) softmax-renormalized."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    return idx, gate.astype(logits.dtype)


def mwu_route(logits, k, capacity, mwu_iters=16):
    """MWU-LP router. Returns (expert_idx (T,k), gate (T,k)).

    Solves the capacity-constrained assignment LP with the paper's
    Algorithm 2 (Newton step search) for a fixed iteration budget, then
    rounds per-token to the top-k of the fractional assignment.
    Gradients flow through the gates (softmax probs at chosen experts);
    the assignment itself is a stop-gradient integer plan, exactly like
    standard top-k routing.
    """
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    affin = jax.lax.stop_gradient(probs.reshape(-1))  # objective weights

    P_op = VStack(ops=(
        ExpertCapRows(inv_cap=jnp.full((E,), 1.0 / capacity, jnp.float32), T=T),
        BoxRows(n=T * E),
    ))
    # objective embedding: <affin, x> >= half the total affinity mass
    # (a conservative reachable bound)
    C_op = VStack(ops=(
        TokenSumRows(inv_k=jnp.asarray(1.0 / k, jnp.float32), T=T, E=E),
        OnesRow(c=affin, inv_bound=jnp.asarray(1.0 / jnp.maximum(affin.sum() * 0.5, 1e-6))),
    ))
    res = solve(
        P_op, C_op,
        MWUOptions(eps=0.25, step_rule="newton", max_iter=mwu_iters, check_packing=False),
    )
    x = jax.lax.stop_gradient(res.x.reshape(T, E))
    # round: top-k of the fractional plan; gates from router probs
    _, idx = jax.lax.top_k(x + 1e-6 * probs, k)  # tie-break by affinity
    gate = jnp.take_along_axis(probs, idx, axis=1)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    return idx, gate.astype(logits.dtype)


def expert_load(idx, E):
    """Tokens assigned per expert — load-balance diagnostic."""
    return jnp.bincount(idx.reshape(-1), length=E)


# ----------------------------------------------------------------------
# MoE layer
# ----------------------------------------------------------------------


def moe_init(key, cfg, dtype):
    d, m = cfg.d_model, cfg.moe
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, m.n_experts), dtype),
        "wg": dense_init(ks[1], (m.n_experts, d, m.d_ff), dtype),
        "wu": dense_init(ks[2], (m.n_experts, d, m.d_ff), dtype),
        "wd": dense_init(ks[3], (m.n_experts, m.d_ff, d), dtype),
    }


def moe_spec(cfg, fsdp: bool):
    dp = "data" if fsdp else None
    ep = cfg.moe.ep_axis
    if ep == "data":
        # expert-parallel over data (serving of >TP-shard models, e.g.
        # dbrx's 16 experts): experts over data, expert-hidden over model.
        e_spec = lambda: P("data", None, TP)
        d_spec = P("data", TP, None)
    elif ep == "matrix":
        # expert count does not divide any axis (mixtral: 8 experts on
        # 16-way axes): shard each expert's matrix 2-D over (data, model)
        # instead — still 256-way fully-sharded weights.
        e_spec = lambda: P(None, "data", TP)
        d_spec = P(None, TP, "data")
    else:
        e_spec = lambda: P(dp, None, TP)
        d_spec = P(dp, TP, None)
    return {
        "router": P(None, None),
        "wg": e_spec(),
        "wu": e_spec(),
        "wd": d_spec,
    }


def _dispatch_group(xt, idx, gate, E, cap, dtype):
    """Sort-based capacity dispatch for ONE token group (all local work).

    xt: (T, d); idx/gate: (T, k). Returns (he (E, cap, d), combine info).
    """
    T, d = xt.shape
    k = idx.shape[1]
    flat_e = idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e)  # stable
    se, st_, sg = flat_e[order], flat_t[order], flat_g[order]
    start = jnp.searchsorted(se, jnp.arange(E))
    rank = jnp.arange(T * k) - start[se]
    valid = rank < cap
    slot = jnp.where(valid, se * cap + rank, E * cap)  # overflow -> scratch
    buf = jnp.zeros((E * cap + 1, d), dtype).at[slot].set(xt[st_])
    return buf[: E * cap].reshape(E, cap, d), (slot, st_, sg, valid)


def _combine_group(ho, info, T, dtype):
    slot, st_, sg, valid = info
    E_cap, d = ho.reshape(-1, ho.shape[-1]).shape
    out_rows = ho.reshape(E_cap, d)
    gathered = out_rows[jnp.minimum(slot, E_cap - 1)]
    w = jnp.where(valid, sg, 0.0).astype(dtype)
    return jnp.zeros((T, d), dtype).at[st_].add(gathered * w[:, None])


def moe_apply(params, x, cfg, mesh_axes=("data", "model"), rng=None):
    """x: (B, S, d) -> (B, S, d). Sort-based capacity dispatch.

    Dispatch is performed in ``cfg.moe_dispatch_groups`` independent token
    groups laid out along the data axis: sorting, capacity ranking and
    the combine scatter stay *shard-local*; only the expert einsums cross
    shards (the EP all-to-all GSPMD inserts). Without grouping, GSPMD
    partitions the global (T*k, d) scatter as replicate+all-reduce — a
    15 TB/device disaster at the dbrx train cell (EXPERIMENTS.md §Perf).
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    G = max(1, getattr(cfg, "moe_dispatch_groups", 1))
    while T % G != 0:  # degenerate smoke shapes
        G //= 2
    Tg = T // G
    cap = int(np.ceil(Tg * k * m.capacity_factor / E))
    cap = max(8, ((cap + 7) // 8) * 8)  # TPU-friendly multiple
    dp = DP(mesh_axes)

    xt = x.reshape(G, Tg, d)
    xt = with_sharding(xt, P(dp, None, None))
    logits = (xt @ params["router"].astype(xt.dtype)).astype(jnp.float32)
    if m.router == "mwu":
        idx, gate = jax.vmap(lambda lg: mwu_route(lg, k, cap, m.mwu_iters))(logits)
    else:
        idx, gate = jax.vmap(lambda lg: topk_route(lg, k))(logits)

    he, info = jax.vmap(
        lambda xg, ig, gg: _dispatch_group(xg, ig, gg, E, cap, x.dtype)
    )(xt, idx, gate)
    # he: (G, E, cap, d) — G on data; expert einsum crosses into the
    # expert sharding (EP all-to-all / weight-stationary, per ep_axis)
    e_shard = "data" if m.ep_axis == "data" else (None if m.ep_axis == "matrix" else dp)
    dp_axes = dp if isinstance(dp, tuple) else (dp,)
    if e_shard in dp_axes:
        # group dim already occupies this axis (multi-pod DP = (pod, data));
        # leave the expert dim to GSPMD — the E-sharded weights still pull
        # the EP all-to-all in the einsum below.
        e_shard = None
    he = with_sharding(he, P(dp, e_shard, None, None))

    hg = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", he, params["wg"].astype(x.dtype),
                   preferred_element_type=x.dtype)
    )
    hu = jnp.einsum("gecd,edf->gecf", he, params["wu"].astype(x.dtype),
                    preferred_element_type=x.dtype)
    ho = jnp.einsum("gecf,efd->gecd", hg * hu, params["wd"].astype(x.dtype),
                    preferred_element_type=x.dtype)

    yt = jax.vmap(lambda h, i: _combine_group(h, i, Tg, x.dtype))(ho, info)
    return with_sharding(yt.reshape(B, S, d), P(dp, None, None))
