"""Mamba-2 SSD layer (state-space duality, arXiv:2405.21060).

Chunked SSD algorithm: within a chunk the recurrence is computed in its
"attention" (quadratic) dual form; across chunks a linear scan carries
the (H, N, P) state. Identical math to the sequential recurrence

    h_t = exp(dt_t A) h_{t-1} + dt_t (B_t ⊗ x_t)
    y_t = C_t . h_t + D x_t

(verified against the naive recurrence oracle in tests/test_mamba2.py).

Decode carries (conv_state, ssm_state) — O(1) per token, which is what
makes the long_500k cell meaningful for this family.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..common import DP, TP, dense_init, with_sharding
from .norms import apply_norm

__all__ = ["mamba2_init", "mamba2_spec", "mamba2_apply", "mamba2_decode", "SSMState", "init_ssm_state"]


class SSMState(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, conv_dim) trailing conv window
    ssm: jax.Array  # (B, H, N, Pd) running state
    pos: jax.Array  # () int32


def _dims(cfg):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    H = s.n_heads(cfg.d_model)
    conv_dim = di + 2 * s.ngroups * s.d_state
    return s, di, H, conv_dim


def init_ssm_state(cfg, batch, dtype=jnp.float32):
    s, di, H, conv_dim = _dims(cfg)
    return SSMState(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        ssm=jnp.zeros((batch, H, s.d_state, s.head_dim), jnp.float32),
        pos=jnp.zeros((), jnp.int32),
    )


def mamba2_init(key, cfg, dtype):
    s, di, H, conv_dim = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    proj_out = 2 * di + 2 * s.ngroups * s.d_state + H  # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], (d, proj_out), dtype),
        "conv_w": dense_init(ks[1], (s.d_conv, conv_dim), dtype, scale=1.0),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 1e-2))).astype(jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], (di, d), dtype, scale=1.0 / np.sqrt(2 * cfg.n_layers)),
    }


def mamba2_spec(cfg, fsdp: bool):
    dp = "data" if fsdp else None
    return {
        "in_proj": P(dp, TP),
        "conv_w": P(None, TP),
        "conv_b": P(TP),
        "A_log": P(TP),
        "D": P(TP),
        "dt_bias": P(TP),
        "norm_scale": P(TP),
        "out_proj": P(TP, dp),
    }


def _split_proj(cfg, proj):
    s, di, H, _ = _dims(cfg)
    gN = s.ngroups * s.d_state
    z, xBC, dt = jnp.split(proj, [di, di + di + 2 * gN], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, b, init_window=None):
    """Depthwise causal conv1d. xBC: (B,S,C); w: (K,C). Returns (out, window)."""
    K = w.shape[0]
    if init_window is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = init_window.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)
    out = sum(xp[:, i : i + xBC.shape[1]] * w[i][None, None, :] for i in range(K))
    new_window = xp[:, -(K - 1) :] if K > 1 else xp[:, :0]
    return jax.nn.silu(out + b[None, None, :]), new_window


def _segsum(a):
    """Stable segment-sum: out[..., i, j] = sum_{j<k<=i} a[..., k] (i>=j)."""
    S = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((S, S), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def _ssd_chunked(x, dt, A, Bm, Cm, chunk):
    """SSD scan. x:(B,S,H,Pd) dt:(B,S,H) A:(H,) Bm/Cm:(B,S,G,N).

    Returns (y (B,S,H,Pd), final_state (B,H,N,Pd)).
    """
    Bsz, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    nc = (S + Q - 1) // Q
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # group-broadcast B/C to heads, fold dt into x
    Bh = jnp.repeat(Bm, rep, axis=2).reshape(Bsz, nc, Q, H, N)
    Ch = jnp.repeat(Cm, rep, axis=2).reshape(Bsz, nc, Q, H, N)
    xc = x.reshape(Bsz, nc, Q, H, Pd)
    dtc = dt.reshape(Bsz, nc, Q, H)
    a = (-jnp.exp(A))[None, None, None, :] * dtc  # log-decay per step (B,nc,Q,H)
    dx = xc * dtc[..., None]

    # --- intra-chunk (quadratic dual form) --------------------------------
    Lseg = _segsum(jnp.moveaxis(a, -1, -2))  # (B,nc,H,Q,Q)
    L = jnp.exp(Lseg)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh) * L
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores, dx)

    # --- chunk states and inter-chunk scan --------------------------------
    cum_a = jnp.cumsum(a, axis=2)  # (B,nc,Q,H)
    total_a = cum_a[:, :, -1]  # (B,nc,H)
    decay_to_end = jnp.exp(total_a[:, :, None] - cum_a)  # (B,nc,Q,H)
    S_c = jnp.einsum("bcqhn,bcqhp->bchnp", Bh * decay_to_end[..., None], dx)

    def step(h_prev, inp):
        S_i, tot_i = inp  # (B,H,N,Pd), (B,H)
        h = h_prev * jnp.exp(tot_i)[..., None, None] + S_i
        return h, h_prev  # emit state BEFORE this chunk

    h0 = jnp.zeros((Bsz, H, N, Pd), jnp.float32)
    h_last, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(S_c, 1, 0).astype(jnp.float32), jnp.moveaxis(total_a, 1, 0))
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B,nc,H,N,Pd) state entering chunk

    y_off = jnp.einsum(
        "bcqhn,bchnp->bcqhp", Ch * jnp.exp(cum_a)[..., None], h_prevs.astype(Ch.dtype)
    )
    y = (y_diag + y_off).reshape(Bsz, nc * Q, H, Pd)
    return y[:, :S], h_last


def mamba2_apply(params, xin, cfg, mesh_axes=("data", "model"), state: SSMState | None = None):
    """Full-sequence SSD. Returns (out (B,S,d), final SSMState or None)."""
    s, di, H, conv_dim = _dims(cfg)
    dp = DP(mesh_axes)
    Bsz, S, d = xin.shape

    proj = xin @ params["in_proj"].astype(xin.dtype)
    z, xBC, dt = _split_proj(cfg, proj)
    xBC, conv_win = _causal_conv(
        xBC, params["conv_w"].astype(xin.dtype), params["conv_b"].astype(xin.dtype),
        None if state is None else state.conv,
    )
    gN = s.ngroups * s.d_state
    xs, Bm, Cm = jnp.split(xBC, [di, di + gN], axis=-1)
    xs = xs.reshape(Bsz, S, H, s.head_dim)
    xs = with_sharding(xs, P(dp, None, TP, None))
    Bm = Bm.reshape(Bsz, S, s.ngroups, s.d_state)
    Cm = Cm.reshape(Bsz, S, s.ngroups, s.d_state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None, :])

    y, h_last = _ssd_chunked(
        xs.astype(jnp.float32), dtv, params["A_log"], Bm.astype(jnp.float32),
        Cm.astype(jnp.float32), s.chunk,
    )
    y = y + xs.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(Bsz, S, di).astype(xin.dtype)
    y = y * jax.nn.silu(z)
    y = apply_norm({"scale": params["norm_scale"]}, y, "rmsnorm", cfg.norm_eps)
    out = y @ params["out_proj"].astype(xin.dtype)
    new_state = None
    if state is not None:
        new_state = SSMState(conv=conv_win, ssm=h_last, pos=state.pos + S)
    return with_sharding(out, P(dp, None, None)), new_state


def mamba2_decode(params, xin, cfg, state: SSMState, mesh_axes=("data", "model")):
    """Single-token recurrence. xin: (B, 1, d)."""
    s, di, H, conv_dim = _dims(cfg)
    Bsz = xin.shape[0]
    proj = xin[:, 0] @ params["in_proj"].astype(xin.dtype)  # (B, proj)
    z, xBC, dt = _split_proj(cfg, proj)
    # conv over stored window + current
    win = jnp.concatenate([state.conv.astype(xin.dtype), xBC[:, None, :]], axis=1)  # (B,K,C)
    w = params["conv_w"].astype(xin.dtype)
    conv_out = jax.nn.silu((win * w[None]).sum(axis=1) + params["conv_b"].astype(xin.dtype))
    gN = s.ngroups * s.d_state
    xs, Bm, Cm = jnp.split(conv_out, [di, di + gN], axis=-1)
    xs = xs.reshape(Bsz, H, s.head_dim).astype(jnp.float32)
    Bm = jnp.repeat(Bm.reshape(Bsz, s.ngroups, s.d_state), H // s.ngroups, axis=1)
    Cm = jnp.repeat(Cm.reshape(Bsz, s.ngroups, s.d_state), H // s.ngroups, axis=1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, :])  # (B,H)
    decay = jnp.exp(-jnp.exp(params["A_log"])[None, :] * dtv)  # (B,H)
    upd = jnp.einsum("bhn,bhp->bhnp", Bm.astype(jnp.float32), xs * dtv[..., None])
    h = state.ssm * decay[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Cm.astype(jnp.float32), h)
    y = y + xs * params["D"][None, :, None]
    y = y.reshape(Bsz, di).astype(xin.dtype) * jax.nn.silu(z)
    y = apply_norm({"scale": params["norm_scale"]}, y, "rmsnorm", cfg.norm_eps)
    out = (y @ params["out_proj"].astype(xin.dtype))[:, None, :]
    return out, SSMState(conv=win[:, 1:], ssm=h, pos=state.pos + 1)
