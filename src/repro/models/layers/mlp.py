"""Dense FFN: SwiGLU (llama-family) and GELU (starcoder2-style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..common import DP, TP, dense_init, with_sharding

__all__ = ["mlp_init", "mlp_spec", "mlp_apply"]


def mlp_init(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "wg": dense_init(ks[0], (d, f), dtype),
            "wu": dense_init(ks[1], (d, f), dtype),
            "wd": dense_init(ks[2], (f, d), dtype),
        }
    return {
        "wu": dense_init(ks[0], (d, f), dtype),
        "wd": dense_init(ks[1], (f, d), dtype),
        "bu": jnp.zeros((f,), dtype),
        "bd": jnp.zeros((d,), dtype),
    }


def mlp_spec(cfg, fsdp: bool):
    dp = "data" if fsdp else None
    if cfg.mlp_type == "swiglu":
        return {"wg": P(dp, TP), "wu": P(dp, TP), "wd": P(TP, dp)}
    return {"wu": P(dp, TP), "wd": P(TP, dp), "bu": P(TP), "bd": P(None)}


def mlp_apply(params, x, cfg, mesh_axes=("data", "model")):
    dp = DP(mesh_axes)
    mm = lambda a, w: jnp.matmul(a, w.astype(a.dtype), preferred_element_type=a.dtype)
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(mm(x, params["wg"])) * mm(x, params["wu"])
    else:
        h = jax.nn.gelu(mm(x, params["wu"]) + params["bu"].astype(x.dtype))
    h = with_sharding(h, P(dp, None, TP))
    out = mm(h, params["wd"])
    if "bd" in params:
        out = out + params["bd"].astype(x.dtype)
    return with_sharding(out, P(dp, None, None))
