"""Architecture plane: layers, model assembly, per-arch configs."""
from .model import DecodeCaches, Model

__all__ = ["Model", "DecodeCaches"]
