"""Model assembly: embeddings -> scanned block stack -> logits.

One Model class serves all 10 assigned architectures; the per-layer
block kind comes from ``cfg.pattern()``:

  * "attn"  — norm→attention→res, norm→(mlp|moe)→res   (dense/moe/enc/vlm)
  * "ssm"   — norm→mamba2→res                           (mamba2)
  * "rglru" — norm→rglru→res, norm→mlp→res              (recurrentgemma)

Layers are grouped into repetitions of the pattern and scanned with
``lax.scan`` (stacked params, leading ``reps`` axis) so the HLO is
O(pattern) rather than O(n_layers) — essential for 60-layer dry-run
compiles — with ``jax.checkpoint`` rematerialization per superblock.

Modality stubs (per instructions): "audio_frames" consumes precomputed
(B,S,d_model) frame embeddings; "vision_text" consumes precomputed patch
embeddings concatenated before the text tokens.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .common import DP, TP, Dtypes, dense_init, with_sharding
from .layers import attention as att
from .layers import mamba2 as m2
from .layers import mlp as mlpmod
from .layers import moe as moemod
from .layers import norms
from .layers import rglru as rg

__all__ = ["Model", "DecodeCaches"]


class DecodeCaches(NamedTuple):
    """Stacked per-pattern-position caches + scalar position counter."""

    scanned: tuple  # one stacked cache pytree per pattern position
    tail: tuple  # unstacked caches for remainder layers
    pos: jax.Array  # () int32 — tokens decoded so far


def _stack_init(fn, key, reps):
    keys = jax.random.split(key, reps)
    return jax.vmap(fn)(keys)


def _prepend_none(spec_tree):
    return jax.tree.map(
        lambda s: P(*((None,) + tuple(s))), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


class Model:
    def __init__(self, cfg, mesh_axes=("data", "model"), fsdp=True):
        self.cfg = cfg
        self.mesh_axes = mesh_axes
        self.fsdp = fsdp
        self.dt = Dtypes(cfg)
        pat = cfg.pattern()
        self.pattern_unit = cfg.block_pattern or (pat[0],)
        k = len(self.pattern_unit)
        self.reps = cfg.n_layers // k
        self.tail_kinds = pat[self.reps * k :]

    # ------------------------------------------------------------------
    # init / specs
    # ------------------------------------------------------------------

    def _block_init(self, kind, key):
        cfg, dtp = self.cfg, self.dt.param
        p = {"ln1": norms.norm_init(cfg.d_model, cfg.norm_type, dtp)}
        if kind == "attn":
            p["attn"] = att.attention_init(key, cfg, dtp)
            p["ln2"] = norms.norm_init(cfg.d_model, cfg.norm_type, dtp)
            if cfg.moe is not None:
                p["moe"] = moemod.moe_init(jax.random.fold_in(key, 1), cfg, dtp)
            else:
                p["mlp"] = mlpmod.mlp_init(jax.random.fold_in(key, 1), cfg, dtp)
        elif kind == "ssm":
            p["ssm"] = m2.mamba2_init(key, cfg, dtp)
        elif kind == "rglru":
            p["rglru"] = rg.rglru_init(key, cfg, dtp)
            p["ln2"] = norms.norm_init(cfg.d_model, cfg.norm_type, dtp)
            p["mlp"] = mlpmod.mlp_init(jax.random.fold_in(key, 1), cfg, dtp)
        else:
            raise ValueError(kind)
        return p

    def _block_spec(self, kind):
        cfg = self.cfg
        s = {"ln1": norms.norm_spec(cfg.norm_type)}
        if kind == "attn":
            s["attn"] = att.attention_spec(cfg, self.fsdp)
            s["ln2"] = norms.norm_spec(cfg.norm_type)
            if cfg.moe is not None:
                s["moe"] = moemod.moe_spec(cfg, self.fsdp)
            else:
                s["mlp"] = mlpmod.mlp_spec(cfg, self.fsdp)
        elif kind == "ssm":
            s["ssm"] = m2.mamba2_spec(cfg, self.fsdp)
        elif kind == "rglru":
            s["rglru"] = rg.rglru_spec(cfg, self.fsdp)
            s["ln2"] = norms.norm_spec(cfg.norm_type)
            s["mlp"] = mlpmod.mlp_spec(cfg, self.fsdp)
        return s

    def init(self, key):
        cfg, dtp = self.cfg, self.dt.param
        V, d = cfg.padded_vocab, cfg.d_model
        kE, kB, kT, kH = jax.random.split(key, 4)
        params = {}
        if cfg.modality == "audio_frames":
            params["frame_proj"] = dense_init(kE, (d, d), dtp)
        params["embed"] = dense_init(kE, (V, d), dtp, scale=np.sqrt(d))
        blocks = {}
        for j, kind in enumerate(self.pattern_unit):
            blocks[f"b{j}"] = _stack_init(
                functools.partial(self._block_init, kind), jax.random.fold_in(kB, j), self.reps
            )
        params["blocks"] = blocks
        tail = {}
        for j, kind in enumerate(self.tail_kinds):
            tail[f"t{j}"] = self._block_init(kind, jax.random.fold_in(kT, j))
        params["tail"] = tail
        params["final_norm"] = norms.norm_init(d, cfg.norm_type, dtp)
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(kH, (d, V), dtp)
        return params

    def param_spec(self):
        cfg = self.cfg
        dp = "data" if self.fsdp else None
        spec = {"embed": P(TP, dp)}
        if cfg.modality == "audio_frames":
            spec["frame_proj"] = P(None, TP)
        spec["blocks"] = {
            f"b{j}": _prepend_none(self._block_spec(kind))
            for j, kind in enumerate(self.pattern_unit)
        }
        spec["tail"] = {
            f"t{j}": self._block_spec(kind) for j, kind in enumerate(self.tail_kinds)
        }
        spec["final_norm"] = norms.norm_spec(cfg.norm_type)
        if not cfg.tie_embeddings:
            spec["lm_head"] = P(dp, TP)
        return spec

    def abstract_params(self):
        """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # ------------------------------------------------------------------
    # block application
    # ------------------------------------------------------------------

    def _apply_block(self, kind, p, x, positions, cache=None, decode=False):
        cfg = self.cfg
        nrm = lambda q, v: norms.apply_norm(q, v, cfg.norm_type, cfg.norm_eps)
        new_cache = cache
        if kind == "attn":
            h = nrm(p["ln1"], x)
            if decode or cache is not None:
                a, new_cache = att.attention_apply(
                    p["attn"], h, cfg, positions=positions, cache=cache, mesh_axes=self.mesh_axes
                )
            else:
                a, _ = att.attention_apply(
                    p["attn"], h, cfg, positions=positions, mesh_axes=self.mesh_axes
                )
            x = x + a
            h = nrm(p["ln2"], x)
            if cfg.moe is not None:
                x = x + moemod.moe_apply(p["moe"], h, cfg, self.mesh_axes)
            else:
                x = x + mlpmod.mlp_apply(p["mlp"], h, cfg, self.mesh_axes)
        elif kind == "ssm":
            h = nrm(p["ln1"], x)
            if decode:
                o, new_cache = m2.mamba2_decode(p["ssm"], h, cfg, cache, self.mesh_axes)
            else:
                o, new_cache = m2.mamba2_apply(p["ssm"], h, cfg, self.mesh_axes, state=cache)
            x = x + o
        elif kind == "rglru":
            h = nrm(p["ln1"], x)
            if decode:
                o, new_cache = rg.rglru_decode(p["rglru"], h, cfg, cache, self.mesh_axes)
            else:
                o, new_cache = rg.rglru_apply(p["rglru"], h, cfg, self.mesh_axes, state=cache)
            x = x + o
            h = nrm(p["ln2"], x)
            x = x + mlpmod.mlp_apply(p["mlp"], h, cfg, self.mesh_axes)
        return x, new_cache

    def _superblock(self, params_j_tree, x, positions, caches=None, decode=False):
        """Apply one repetition of the pattern; caches aligned by position."""
        new_caches = []
        for j, kind in enumerate(self.pattern_unit):
            c = None if caches is None else caches[j]
            x, nc = self._apply_block(kind, params_j_tree[f"b{j}"], x, positions, c, decode)
            new_caches.append(nc)
        return x, tuple(new_caches)

    # ------------------------------------------------------------------
    # embeddings / logits
    # ------------------------------------------------------------------

    def embed(self, params, batch):
        """batch: dict with 'tokens' and optional 'frames'/'patches'."""
        cfg = self.cfg
        dp = DP(self.mesh_axes)
        emb = params["embed"].astype(self.dt.compute)
        if cfg.modality == "audio_frames":
            x = batch["frames"].astype(self.dt.compute) @ params["frame_proj"].astype(self.dt.compute)
        elif cfg.modality == "vision_text":
            tok = emb[batch["tokens"]]  # (B, S_text, d)
            if "patches" in batch:  # decode steps are text-only
                patches = batch["patches"].astype(self.dt.compute)
                tok = jnp.concatenate([patches, tok], axis=1)
            x = tok
        else:
            x = emb[batch["tokens"]]
        return with_sharding(x, P(dp, None, None))

    def logits(self, params, x):
        cfg = self.cfg
        head = (
            params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        ).astype(self.dt.compute)
        out = (x @ head).astype(self.dt.logit)
        V = cfg.padded_vocab
        if V != cfg.vocab_size:  # mask pad-vocab slots
            pad_mask = jnp.arange(V) >= cfg.vocab_size
            out = jnp.where(pad_mask[None, None, :], -1e30, out)
        return with_sharding(out, P(DP(self.mesh_axes), None, TP))

    # ------------------------------------------------------------------
    # forward (train / encode / prefill-logits)
    # ------------------------------------------------------------------

    def forward(self, params, batch, remat: Optional[bool] = None):
        """Full-sequence forward -> hidden states (B, S, d)."""
        cfg = self.cfg
        x = self.embed(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        use_remat = cfg.remat == "full" if remat is None else remat

        def body(x, pblock):
            out, _ = self._superblock(pblock, x, positions)
            return out, None

        if use_remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["blocks"])
        for j, kind in enumerate(self.tail_kinds):
            x, _ = self._apply_block(kind, params["tail"][f"t{j}"], x, positions)
        return norms.apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------

    def _make_cache(self, kind, batch, max_len):
        cfg = self.cfg
        if kind == "attn":
            return att.init_kv_cache(cfg, batch, max_len, dtype=self.dt.compute)
        if kind == "ssm":
            return m2.init_ssm_state(cfg, batch)
        if kind == "rglru":
            return rg.init_rglru_state(cfg, batch)
        raise ValueError(kind)

    def init_caches(self, batch, max_len) -> DecodeCaches:
        scanned = []
        for kind in self.pattern_unit:
            concrete = self._make_cache(kind, batch, max_len)
            stacked = jax.tree.map(
                lambda c: jnp.broadcast_to(c[None], (self.reps,) + c.shape), concrete
            )
            scanned.append(stacked)
        tail = tuple(self._make_cache(k, batch, max_len) for k in self.tail_kinds)
        return DecodeCaches(scanned=tuple(scanned), tail=tail, pos=jnp.zeros((), jnp.int32))

    def cache_spec(self, shard_seq=True, shard_batch=True):
        """PartitionSpecs for DecodeCaches (DESIGN.md §5 decode layout).

        shard_batch=False for cells whose global batch does not divide
        the DP axes (long_500k's single request)."""
        dp = DP(self.mesh_axes) if shard_batch else None
        seq = TP if shard_seq else None

        def one(kind, stacked):
            lead = (None,) if stacked else ()
            if kind == "attn":
                return att.KVCache(
                    k=P(*lead, dp, seq, None, None),
                    v=P(*lead, dp, seq, None, None),
                    slot_pos=P(*lead, seq),
                )
            if kind == "ssm":
                return m2.SSMState(
                    conv=P(*lead, dp, None, TP), ssm=P(*lead, dp, TP, None, None),
                    pos=P(*lead) if stacked else P(),
                )
            if kind == "rglru":
                return rg.RGLRUState(
                    conv=P(*lead, dp, None, TP), h=P(*lead, dp, TP),
                    pos=P(*lead) if stacked else P(),
                )

        return DecodeCaches(
            scanned=tuple(one(k, True) for k in self.pattern_unit),
            tail=tuple(one(k, False) for k in self.tail_kinds),
            pos=P(),
        )

    def decode_step(self, params, caches: DecodeCaches, tokens):
        """One decode step. tokens: (B, 1) (or frames (B,1,d)). Returns
        (logits (B, 1, V), new caches)."""
        cfg = self.cfg
        batch = {"tokens": tokens} if cfg.modality != "audio_frames" else {"frames": tokens}
        x = self.embed(params, batch)
        positions = jnp.full((1,), caches.pos, jnp.int32)

        def body(x, inp):
            pblock, cache = inp
            out, ncache = self._superblock(pblock, x, positions, cache, decode=True)
            return out, ncache

        # scan over reps, threading caches as scanned inputs/outputs
        def scan_body(carry, inp):
            x = carry
            x, ncache = body(x, inp)
            return x, ncache

        x, new_scanned = jax.lax.scan(
            scan_body, x, (params["blocks"], caches.scanned)
        )
        new_tail = []
        for j, kind in enumerate(self.tail_kinds):
            x, nc = self._apply_block(
                kind, params["tail"][f"t{j}"], x, positions, caches.tail[j], decode=True
            )
            new_tail.append(nc)
        x = norms.apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        logits = self.logits(params, x)
        return logits, DecodeCaches(
            scanned=new_scanned, tail=tuple(new_tail), pos=caches.pos + 1
        )

    def prefill(self, params, batch, max_len):
        """Process a prompt, filling caches; returns (last-token logits, caches)."""
        cfg = self.cfg
        x = self.embed(params, batch)
        B, S = x.shape[0], x.shape[1]
        caches = self.init_caches(B, max_len)
        positions = jnp.arange(S, dtype=jnp.int32)

        def scan_body(x, inp):
            pblock, cache = inp
            x, ncache = self._superblock(pblock, x, positions, cache, decode=False)
            return x, ncache

        x, new_scanned = jax.lax.scan(scan_body, x, (params["blocks"], caches.scanned))
        new_tail = []
        for j, kind in enumerate(self.tail_kinds):
            x, nc = self._apply_block(
                kind, params["tail"][f"t{j}"], x, positions, caches.tail[j]
            )
            new_tail.append(nc)
        x = norms.apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        logits = self.logits(params, x[:, -1:])
        return logits, DecodeCaches(
            scanned=new_scanned, tail=tuple(new_tail), pos=jnp.asarray(S, jnp.int32)
        )
