"""internvl2-26b [vlm]: 48L, d_model 6144, 48H GQA kv=8, d_ff 16384,
vocab 92553 (arXiv:2404.16821) — InternViT + InternLM2 backbone.

Per instructions the vision frontend is a STUB: input_specs() provides
precomputed patch embeddings (B, n_patches, d_model) concatenated before
the text tokens. Vocab padded 92553 -> 92672. Full attention.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab_size=92553,
    modality="vision_text",
    n_vision_patches=1024,
    mlp_type="swiglu",
)
