"""recurrentgemma-9b [hybrid]: 38L, d_model 4096, 16H MQA kv=1,
d_ff 12288, vocab 256000 (arXiv:2402.19427) — RG-LRU + local attention,
pattern (recurrent, recurrent, local-attn). Sub-quadratic (state + 2048
window) => runs the long_500k cell.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_head=256,
    d_ff=12288,
    vocab_size=256000,
    sliding_window=2048,
    block_pattern=("rglru", "rglru", "attn"),
    rnn_width=4096,
    mlp_type="swiglu",
)
