"""starcoder2-15b [dense]: 40L, d_model 6144, 48H GQA kv=4, d_ff 24576,
vocab 49152 (arXiv:2402.19173; hf). GQA + RoPE; GELU MLP + layernorm
(starcoder2 keeps the GPT-style MLP). Full attention.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_head=128,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=100_000.0,
    mlp_type="gelu",
    norm_type="layernorm",
)
