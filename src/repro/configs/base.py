"""Model/parallelism configuration schema for the architecture plane.

Every assigned architecture is a :class:`ModelConfig` in its own module
(one file per arch, exact pool numbers). ``reduced()`` derives the tiny
smoke-test variant of the same family.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "MeshConfig", "ShardingProfile"]


def _ceil_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden
    router: str = "topk"  # "topk" | "mwu"  (MWU = the paper's technique)
    capacity_factor: float = 1.25
    mwu_iters: int = 16  # in-graph MWU iterations for router="mwu"
    router_jitter: float = 0.0
    # shard experts over this mesh axis ("data" enables expert-parallel
    # serving of models whose weights exceed a TP-16 shard, e.g. dbrx)
    ep_axis: str = "model"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD hyperparameters (arXiv:2405.21060)."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256
    ngroups: int = 1  # B/C groups

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads

    # attention
    rope_theta: float = 10_000.0
    qkv_bias: bool = False  # Qwen1.5 uses QKV bias
    sliding_window: Optional[int] = None  # SWA (Mixtral) / local attn (RG)
    causal: bool = True
    attn_impl: str = "chunked"  # "dense" | "chunked" | "pallas"
    attn_chunk: int = 1024  # kv-block size for chunked/flash attention

    # mlp
    mlp_type: str = "swiglu"  # "swiglu" | "gelu"

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # hybrid layer pattern, e.g. ("rglru", "rglru", "attn"); None => uniform
    block_pattern: Optional[tuple] = None
    # recurrent width for rglru blocks (defaults to d_model)
    rnn_width: int = 0

    norm_type: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # modality stubs (per instructions: frontends are precomputed embeddings)
    modality: str = "text"  # "text" | "audio_frames" | "vision_text"
    n_vision_patches: int = 1024  # [vlm] patch count inside the sequence

    # numerics / training
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    remat: str = "full"  # "none" | "full"
    logit_dtype: str = "float32"
    # pad vocab so 16-way model sharding divides it (DESIGN.md §3)
    vocab_pad_multiple: int = 256
    # MoE dispatch locality: number of independent token groups laid out
    # along the data axis (set to the DP shard count by launchers); 1 =
    # single global dispatch (only safe on one device) — EXPERIMENTS §Perf.
    moe_dispatch_groups: int = 1

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(self.n_heads, 1))
        if self.family == "hybrid" and self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)

    @property
    def padded_vocab(self) -> int:
        return _ceil_to(self.vocab_size, self.vocab_pad_multiple)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k cell (DESIGN.md shape-cell skips)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    @property
    def has_decode(self) -> bool:
        return self.family != "encoder"

    def pattern(self) -> tuple:
        """Per-layer block kinds, length n_layers."""
        if self.block_pattern is None:
            kind = {"ssm": "ssm"}.get(self.family, "attn")
            return (kind,) * self.n_layers
        p = self.block_pattern
        reps = (self.n_layers + len(p) - 1) // len(p)
        return (p * reps)[: self.n_layers]

    def n_params(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        per_attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        if self.mlp_type == "swiglu":
            per_mlp = 3 * d * f
        else:
            per_mlp = 2 * d * f
        if self.moe is not None:
            per_mlp = self.moe.n_experts * (3 * d * self.moe.d_ff) + d * self.moe.n_experts
        total = emb
        for kind in self.pattern():
            if kind == "attn":
                total += per_attn + per_mlp
            elif kind == "ssm":
                di = self.ssm.d_inner(d)
                total += d * (2 * di + 2 * self.ssm.ngroups * self.ssm.d_state + self.ssm.n_heads(d)) + di * d
                total += per_mlp if f > 0 else 0
            elif kind == "rglru":
                w = self.rnn_width
                total += 2 * d * w + w * d + 2 * w * w // 1  # in/out + gates (block-diag approx)
                total += per_mlp
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts)."""
        if self.moe is None:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        per_attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        act_mlp = self.moe.top_k * 3 * d * self.moe.d_ff + d * self.moe.n_experts
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return emb + L * (per_attn + act_mlp)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2 if self.block_pattern is None else len(self.block_pattern or (1,))),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            d_head=32,
            d_ff=256 if self.d_ff > 0 else 0,
            vocab_size=256,
            rnn_width=128 if self.family == "hybrid" else 0,
            sliding_window=16 if self.sliding_window else None,
            attn_chunk=16,
            n_vision_patches=8,
            dtype="float32",
            param_dtype="float32",
            remat="none",
            name=self.name + "-reduced",
        )
        if self.moe is not None:
            kw["moe"] = replace(self.moe, n_experts=4, top_k=2, d_ff=64, ep_axis="model")
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=16, chunk=8)
        if self.block_pattern is not None:
            kw["n_layers"] = len(self.block_pattern)
        return replace(self, **kw)


@dataclass(frozen=True)
class MeshConfig:
    """Logical mesh + sharding profile selection."""

    shape: tuple = (16, 16)
    axes: tuple = ("data", "model")
    profile: str = "train"  # "train" (fsdp+tp) | "serve" (tp + ep)

    @property
    def n_devices(self):
        import math

        return math.prod(self.shape)

    @property
    def data_axes(self) -> tuple:
        """Axes batch is sharded over (pod absorbs into data parallelism)."""
        return tuple(a for a in self.axes if a in ("pod", "data"))


@dataclass(frozen=True)
class ShardingProfile:
    """How parameters/activations map onto the mesh (DESIGN.md §5)."""

    params_fsdp: bool = True  # shard the non-TP param dim over data (ZeRO-3)
    expert_axis: str = "model"  # mesh axis for MoE expert dim
    shard_kv_seq: bool = True  # decode KV cache: shard seq over model
