"""yi-34b [dense]: 60L, d_model 7168, 56H GQA kv=8, d_ff 20480, vocab 64000.

Llama-architecture GQA decoder (arXiv:2403.04652; hf). SwiGLU, RMSNorm,
RoPE. Pure full attention => long_500k cell is skipped (DESIGN.md §6).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    mlp_type="swiglu",
)
