"""Assigned input-shape cells + ``input_specs()`` (ShapeDtypeStruct stand-ins).

Four cells per architecture (40 total):

  train_4k    seq 4096  x global_batch 256   -> train_step
  prefill_32k seq 32768 x global_batch 32    -> serve_step (prefill/encode)
  decode_32k  KV len 32768 x global_batch 128 -> serve_step (1 new token)
  long_500k   KV len 524288 x global_batch 1  -> serve_step (1 new token)

Skip rules (DESIGN.md §6): encoder-only archs have no decode cells;
long_500k runs only for sub-quadratic archs (ssm / hybrid / SWA).
``input_specs`` returns weak-type-correct ShapeDtypeStructs — never
allocating — exactly what jit.lower consumes in the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["SHAPES", "ShapeCell", "applicable", "skip_reason", "input_specs", "cells_for"]


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    step: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def skip_reason(cfg, cell: ShapeCell) -> str | None:
    if cell.step == "decode" and not cfg.has_decode:
        return "encoder-only: no autoregressive step exists"
    if cell.name == "long_500k" and not cfg.is_subquadratic:
        return "pure full attention: 512k dense KV cache is not meaningful"
    return None


def applicable(cfg, cell: ShapeCell) -> bool:
    return skip_reason(cfg, cell) is None


def cells_for(cfg):
    return [c for c in SHAPES.values() if applicable(cfg, c)]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, cell: ShapeCell) -> dict:
    """Model inputs for this (arch x shape) cell, as ShapeDtypeStructs.

    train:   {"tokens"|"frames"[, "patches"], "targets", "loss_mask"}
    prefill: {"tokens"|"frames"[, "patches"]}
    decode:  {"tokens" (B,1) | "frames" (B,1,d)}  (+ cache built separately)
    """
    B, S = cell.global_batch, cell.seq_len
    d = cfg.d_model
    out = {}
    if cell.step == "decode":
        if cfg.modality == "audio_frames":
            out["frames"] = _sds((B, 1, d), jnp.float32)
        else:
            out["tokens"] = _sds((B, 1), jnp.int32)
        return out

    if cfg.modality == "audio_frames":
        out["frames"] = _sds((B, S, d), jnp.float32)
    elif cfg.modality == "vision_text":
        npt = cfg.n_vision_patches
        out["patches"] = _sds((B, npt, d), jnp.float32)
        out["tokens"] = _sds((B, S - npt), jnp.int32)
    else:
        out["tokens"] = _sds((B, S), jnp.int32)

    if cell.step == "train":
        out["targets"] = _sds((B, S), jnp.int32)
        out["loss_mask"] = _sds((B, S), jnp.float32)
    return out


def concrete_inputs(cfg, cell: ShapeCell, seed: int = 0) -> dict:
    """Small-footprint concrete batch (reduced configs / smoke tests)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    spec = input_specs(cfg, cell)
    out = {}
    for k, s in spec.items():
        if s.dtype == jnp.int32:
            hi = cfg.vocab_size if k in ("tokens", "targets") else 2
            out[k] = jnp.asarray(rng.integers(0, hi, size=s.shape, dtype=np.int32))
        else:
            out[k] = jnp.asarray(rng.standard_normal(s.shape).astype(np.float32) * 0.02)
    if "loss_mask" in out:
        out["loss_mask"] = jnp.ones(spec["loss_mask"].shape, jnp.float32)
    return out
