"""mamba2-1.3b [ssm]: 48L, d_model 2048, attention-free, vocab 50280,
ssm_state=128 (arXiv:2405.21060). SSD layers only (d_ff=0). Sub-quadratic
=> runs the long_500k cell. Vocab padded 50280 -> 50432 for 16-way TP
(DESIGN.md §3).
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True,
)
