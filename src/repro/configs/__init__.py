"""Per-architecture configs (exact pool specs) + shape cells."""
from .base import MeshConfig, ModelConfig, MoEConfig, SSMConfig
from .registry import ARCH_IDS, all_configs, get
from .shapes import SHAPES, ShapeCell, applicable, cells_for, input_specs, skip_reason

__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "MeshConfig",
    "ARCH_IDS", "get", "all_configs",
    "SHAPES", "ShapeCell", "applicable", "cells_for", "input_specs", "skip_reason",
]
