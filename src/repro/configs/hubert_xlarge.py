"""hubert-xlarge [audio]: 48L encoder-only, d_model 1280, 16H MHA,
d_ff 5120, vocab 504 (arXiv:2106.07447) — same arch as wav2vec2.

Encoder-only: bidirectional attention, no decode cells (DESIGN.md §6);
prefill_32k lowers the encode forward. The conv feature extractor is a
STUB: input_specs() provides precomputed frames (B, S, d_model).
Vocab padded 504 -> 512.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_head=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    modality="audio_frames",
    mlp_type="gelu",
    norm_type="layernorm",
)
