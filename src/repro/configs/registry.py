"""Architecture registry: ``get(arch_id)`` -> ModelConfig.

One module per assigned architecture (exact pool numbers); IDs match the
assignment table. ``mwu-graph`` is the paper's own workload as a
dry-runnable config (distributed MWU on a synthetic graph).
"""
from __future__ import annotations

from importlib import import_module

ARCH_IDS = [
    "yi-34b",
    "qwen1.5-32b",
    "starcoder2-15b",
    "minitron-4b",
    "mamba2-1.3b",
    "dbrx-132b",
    "mixtral-8x22b",
    "internvl2-26b",
    "hubert-xlarge",
    "recurrentgemma-9b",
]

_MODULES = {
    "yi-34b": "yi_34b",
    "qwen1.5-32b": "qwen1_5_32b",
    "starcoder2-15b": "starcoder2_15b",
    "minitron-4b": "minitron_4b",
    "mamba2-1.3b": "mamba2_1_3b",
    "dbrx-132b": "dbrx_132b",
    "mixtral-8x22b": "mixtral_8x22b",
    "internvl2-26b": "internvl2_26b",
    "hubert-xlarge": "hubert_xlarge",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def get(arch_id: str):
    if arch_id.endswith("-mwu"):  # MoE variant with the MWU LP router
        base = get(arch_id[: -len("-mwu")])
        from dataclasses import replace

        assert base.moe is not None, f"{arch_id}: MWU router needs an MoE arch"
        return replace(base, name=arch_id, moe=replace(base.moe, router="mwu"))
    mod = import_module(f".{_MODULES[arch_id]}", package=__package__)
    return mod.CONFIG


def all_configs():
    return {a: get(a) for a in ARCH_IDS}
