"""Loss + train_step factory.

Cross-entropy is computed in sequence chunks (``loss_chunk``) so the
(B, S, vocab) f32 logits tensor is never materialized — at the train_4k
cell with a 256k vocab that tensor would be 1 TB. Microbatching
(gradient accumulation via lax.scan) and bf16 compute with f32 master
params come standard. The TP logit all-reduce and the DP gradient
reduce-scatter both live inside this one jitted program, so XLA's
scheduler can overlap them with compute (DESIGN.md §5).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state

__all__ = ["TrainState", "make_train_state", "make_train_step", "chunked_ce_loss"]


class TrainState(NamedTuple):
    params: dict
    opt: OptState
    step: jax.Array


def make_train_state(model, rng) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt=init_opt_state(params), step=jnp.zeros((), jnp.int32))


def chunked_ce_loss(model, params, batch, chunk: int = 512):
    """Next-token CE, chunked over the sequence. Uses batch['targets'] and
    batch['loss_mask'] (mask also covers VLM patch positions & padding)."""
    h = model.forward(params, batch)  # (B, S, d)
    B, S, d = h.shape
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    chunk = min(chunk, S)
    n_chunks = S // chunk
    rem = S - n_chunks * chunk

    head = (
        params["embed"].T if model.cfg.tie_embeddings else params["lm_head"]
    )

    def ce_of(hs, ts, ms):
        logits = (hs.astype(model.dt.compute) @ head.astype(model.dt.compute)).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, ts[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - ll) * ms), jnp.sum(ms)

    def body(carry, xs):
        tot, cnt = carry
        hs, ts, ms = xs
        l, c = ce_of(hs, ts, ms)
        return (tot + l, cnt + c), None

    hc = h[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, d).swapaxes(0, 1)
    tc = targets[:, : n_chunks * chunk].reshape(B, n_chunks, chunk).swapaxes(0, 1)
    mc = mask[:, : n_chunks * chunk].reshape(B, n_chunks, chunk).swapaxes(0, 1)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hc, tc, mc))
    if rem:
        l, c = ce_of(h[:, -rem:], targets[:, -rem:], mask[:, -rem:])
        tot, cnt = tot + l, cnt + c
    return tot / jnp.maximum(cnt, 1.0)


def make_train_step(model, opt_cfg: AdamWConfig, microbatches: int = 1, loss_chunk: int = 512):
    """Returns train_step(state, batch) -> (state, metrics); jit-ready."""

    def loss_fn(params, batch):
        return chunked_ce_loss(model, params, batch, chunk=loss_chunk)

    def train_step(state: TrainState, batch):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            def split(x):
                B = x.shape[0]
                mb = B // microbatches
                return x.reshape(microbatches, mb, *x.shape[1:])

            mbatches = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                tot_l, tot_g = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                return (tot_l + l, jax.tree.map(jnp.add, tot_g, g)), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(acc_body, (jnp.zeros(()), zero_g), mbatches)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        new_params, new_opt, om = adamw_update(opt_cfg, grads, state.opt, state.params)
        metrics = {"loss": loss, **om}
        return TrainState(params=new_params, opt=new_opt, step=state.step + 1), metrics

    return train_step
