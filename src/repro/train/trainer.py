"""Fault-tolerant training loop (DESIGN.md §5).

Invariants that make restarts exact:
  * the data pipeline is stateless-seekable: batch = f(seed, step);
  * checkpoints bundle (params, opt state, step) and commit atomically;
  * on start, the trainer restores the newest valid checkpoint and
    *continues at the exact step* — a crashed/restarted run is bitwise
    the uninterrupted run (asserted by tests/test_substrate.py).

Straggler / elastic posture (single-host CPU exercises the logic only):
  * a per-step wall-clock watchdog records slow steps; in a pod
    deployment the surrounding launcher uses it to trigger a
    checkpoint-and-reshard to a smaller healthy mesh — mesh shape is a
    constructor argument everywhere (Model/Trainer never hard-code it),
    so an elastic down-shift is restore() on a new mesh;
  * checkpoints are written asynchronously (one-deep pipeline) so the
    loop never blocks on serialization of the previous save.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..checkpoint import ckpt as ckpt_lib
from ..data.synthetic import TokenPipeline
from ..models import Model
from .optimizer import AdamWConfig
from .step import TrainState, make_train_state, make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclass
class TrainerConfig:
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    log_every: int = 10
    microbatches: int = 1
    loss_chunk: int = 512
    straggler_factor: float = 3.0  # step > factor * median => flagged
    opt: AdamWConfig = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=1000)


class Trainer:
    def __init__(self, model_cfg, tc: TrainerConfig, mesh=None):
        self.cfg = model_cfg
        self.tc = tc
        self.mesh = mesh
        self.model = Model(model_cfg, mesh_axes=mesh.axis_names if mesh else ("data", "model"),
                           fsdp=mesh is not None)
        self.pipe = TokenPipeline(model_cfg.vocab_size, tc.seq_len, tc.global_batch, tc.seed)
        self.step_fn = jax.jit(
            make_train_step(self.model, tc.opt, tc.microbatches, tc.loss_chunk)
        )
        self.last_metrics = {}
        self.slow_steps: list[int] = []

    def _init_state(self) -> tuple[TrainState, int]:
        state = make_train_state(self.model, jax.random.PRNGKey(self.tc.seed))
        start = 0
        if self.tc.ckpt_dir:
            restored, step = ckpt_lib.restore_latest(self.tc.ckpt_dir, state)
            if restored is not None:
                state, start = restored, int(step)
        return state, start

    def run(self):
        state, start = self._init_state()
        times = []
        for it in range(start, self.tc.steps):
            batch = {k: jnp.asarray(v) for k, v in self.pipe.batch_at(it).items()}
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            times.append(dt)
            # straggler watchdog (elastic trigger in pod deployments)
            med = sorted(times)[len(times) // 2]
            if len(times) > 5 and dt > self.tc.straggler_factor * med:
                self.slow_steps.append(it)
            if self.tc.ckpt_dir and (it + 1) % self.tc.ckpt_every == 0:
                ckpt_lib.save_async(self.tc.ckpt_dir, it + 1, state)
            if (it + 1) % self.tc.log_every == 0:
                print(f"step {it+1}: loss={metrics['loss']:.4f} "
                      f"gnorm={metrics['grad_norm']:.3f} {dt*1e3:.0f}ms", flush=True)
            self.last_metrics = metrics
        if self.tc.ckpt_dir:
            ckpt_lib.save_async(self.tc.ckpt_dir, self.tc.steps, state)
            ckpt_lib.wait_pending()
        self.final_state = state
        return state
