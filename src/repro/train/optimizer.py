"""AdamW + warmup-cosine schedule + global-norm clipping (no optax).

Optimizer moments inherit the parameter PartitionSpecs: with FSDP param
sharding (train profile) the moments are fully sharded over data x model
— ZeRO-style; there is no replicated optimizer state anywhere.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "opt_state_spec", "adamw_update", "lr_at"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    mu: dict
    nu: dict
    count: jax.Array


def init_opt_state(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def opt_state_spec(param_spec) -> OptState:
    from jax.sharding import PartitionSpec as P

    return OptState(mu=param_spec, nu=param_spec, count=P())


def lr_at(cfg: AdamWConfig, step):
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.min_lr_frac * cfg.lr + 0.5 * (1 - cfg.min_lr_frac) * cfg.lr * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, grads, state: OptState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = lr_at(cfg, state.count)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        step = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        p2 = p.astype(jnp.float32) - lr * (step + wd * p.astype(jnp.float32))
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(mu=new_mu, nu=new_nu, count=count), {
        "grad_norm": gnorm,
        "lr": lr,
    }
