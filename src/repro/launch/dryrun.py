import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
#   This flag lives ONLY here (dry-run); tests/benches see 1 device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each runnable cell (see configs.shapes.skip_reason) this driver:
  1. builds the production mesh (16,16) or (2,16,16),
  2. lowers the right step function with full-size ShapeDtypeStruct
     inputs and the profile's in/out shardings,
  3. compiles (proving the distribution config is coherent),
  4. records memory_analysis / cost_analysis / the trip-count-aware HLO
     roofline terms (utils/hlo.py) into experiments/dryrun/*.json.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --list
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, get, input_specs, skip_reason
from ..configs.shapes import SHAPES
from ..models import Model
from ..models.common import DP
from ..train.optimizer import AdamWConfig, init_opt_state, opt_state_spec
from ..train.step import TrainState, make_train_step
from ..utils.compat import shard_map
from ..utils.hlo import analyze_hlo
from ..utils.roofline import roofline_terms, model_flops_estimate
from .mesh import make_production_mesh, sharding_for

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def dp_divides(mesh, global_batch: int) -> bool:
    import math

    dp_size = math.prod(
        mesh.shape[a] for a in mesh.axis_names if a in ("pod", "data")
    )
    return global_batch % dp_size == 0


def batch_sharding(mesh, specs, cfg, shard_batch=True):
    dp = DP(mesh.axis_names) if shard_batch else None
    out = {}
    for k, s in specs.items():
        out[k] = NamedSharding(mesh, P(dp, *([None] * (len(s.shape) - 1))))
    return out


def run_cell(arch: str, shape: str, mesh_kind: str, loss_chunk=512, attn_chunk=None,
             extra_tag: str = "", decode_shard_seq=True, remat=None):
    from dataclasses import replace
    cell = SHAPES[shape]
    cfg = get(arch)
    if attn_chunk is not None:
        cfg = replace(cfg, attn_chunk=attn_chunk)
    if remat is not None:
        cfg = replace(cfg, remat=remat)
    reason = skip_reason(cfg, cell)
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "step": cell.step,
        "tag": extra_tag, "ok": False,
    }
    if reason is not None:
        rec.update({"skipped": True, "reason": reason})
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "pod2"))
    n_dev = mesh.devices.size
    train = cell.step == "train"
    if cfg.moe is not None:
        import math
        from dataclasses import replace
        dp_size = math.prod(mesh.shape[a] for a in mesh.axis_names if a in ("pod", "data"))
        cfg = replace(cfg, moe_dispatch_groups=dp_size)
    model = Model(cfg, mesh_axes=mesh.axis_names, fsdp=train)
    specs = input_specs(cfg, cell)
    shard_batch = dp_divides(mesh, cell.global_batch)
    in_batch_shard = batch_sharding(mesh, specs, cfg, shard_batch=shard_batch)

    t0 = time.perf_counter()
    with mesh:
        if cell.step == "train":
            opt_cfg = AdamWConfig()
            step_fn = make_train_step(model, opt_cfg, loss_chunk=loss_chunk)
            pspec = model.param_spec()
            state_shard = TrainState(
                params=sharding_for(mesh, pspec),
                opt=sharding_for(mesh, opt_state_spec(pspec)),
                step=NamedSharding(mesh, P()),
            )
            aparams = model.abstract_params()
            abstract_state = TrainState(
                params=aparams,
                opt=jax.eval_shape(init_opt_state, aparams),
                step=jax.ShapeDtypeStruct((), jnp.int32),
            )
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_shard, in_batch_shard),
                out_shardings=(state_shard, None),
                donate_argnums=(0,),
            ).lower(abstract_state, specs)
        elif cell.step == "prefill":
            def serve_prefill(params, batch):
                return model.prefill(params, batch, max_len=cell.seq_len)

            pshard = sharding_for(mesh, model.param_spec())
            lowered = jax.jit(
                serve_prefill, in_shardings=(pshard, in_batch_shard)
            ).lower(model.abstract_params(), specs)
        else:  # decode
            def serve_decode(params, caches, batch):
                tok = batch.get("tokens", batch.get("frames"))
                return model.decode_step(params, caches, tok)

            pshard = sharding_for(mesh, model.param_spec())
            cshard = sharding_for(
                mesh,
                model.cache_spec(shard_seq=decode_shard_seq, shard_batch=shard_batch),
            )
            abstract_caches = jax.eval_shape(
                lambda: model.init_caches(cell.global_batch, cell.seq_len)
            )
            lowered = jax.jit(
                serve_decode,
                in_shardings=(pshard, cshard, in_batch_shard),
                out_shardings=(None, cshard),
                donate_argnums=(1,),
            ).lower(model.abstract_params(), abstract_caches, specs)

        rec["lower_s"] = round(time.perf_counter() - t0, 2)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t1, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
        "output_bytes": getattr(ma, "output_size_in_bytes", None),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
    }
    ca = compiled.cost_analysis() or {}
    rec["cost_analysis"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }
    t2 = time.perf_counter()
    hlo = analyze_hlo(compiled.as_text(), num_partitions=n_dev)
    rec["analyze_s"] = round(time.perf_counter() - t2, 2)
    rec["hlo"] = hlo.as_dict()
    tokens = cell.global_batch * (cell.seq_len if cell.step != "decode" else 1)
    mf = model_flops_estimate(cfg, cell)
    rec["model_flops"] = mf
    rec["roofline"] = roofline_terms(hlo, n_devices=n_dev, model_flops=mf["total"])
    rec["tokens"] = tokens
    rec["ok"] = True
    return rec


def run_mwu_cell(mesh_kind: str, scale: int = 22, edgefactor: int = 16):
    """Dry-run the paper's own workload: distributed MWU matching on a
    synthetic 2^scale-vertex graph, 2-D partitioned over the production
    mesh; multi-pod runs pod-parallel bound search (DESIGN.md §5)."""
    from ..core.mwu_dist import make_pod_parallel_solver, _dist_solve_local
    from ..core.mwu import make_eta

    mesh = make_production_mesh(multi_pod=(mesh_kind == "pod2"))
    n_dev = mesh.devices.size
    G = 16
    n = 1 << scale
    m = edgefactor * n
    block = (n + G - 1) // G
    e_cell = int(m / (G * G) * 1.3)
    rec = {"arch": "mwu-graph", "shape": f"match-2^{scale}", "mesh": mesh_kind,
           "step": "mwu", "ok": False}

    u = jax.ShapeDtypeStruct((G, G, e_cell), jnp.int32)
    v = jax.ShapeDtypeStruct((G, G, e_cell), jnp.int32)
    msk = jax.ShapeDtypeStruct((G, G, e_cell), jnp.bool_)

    t0 = time.perf_counter()
    with mesh:
        if mesh_kind == "pod2":
            fn = make_pod_parallel_solver(mesh, G, block, n, m, ls_cap=9)
            bounds = jax.ShapeDtypeStruct((2,), jnp.float32)
            shardings = (
                NamedSharding(mesh, P("pod")),
                NamedSharding(mesh, P("data", "model", None)),
                NamedSharding(mesh, P("data", "model", None)),
                NamedSharding(mesh, P("data", "model", None)),
            )
            lowered = jax.jit(fn, in_shardings=shardings).lower(bounds, u, v, msk)
        else:
            eta = jnp.asarray(make_eta(n + 1, 0.1), jnp.float32)

            def single(u, v, msk, x0):
                def inner(u, v, msk, x0):
                    out = _dist_solve_local(
                        G, block, n, eta, 0.1, jnp.float32(1.0 / (n / 4)), 5000,
                        u[0, 0], v[0, 0], msk[0, 0], x0[0, 0], ls_cap=9,
                    )
                    x, *rest = out
                    return (x[None, None], *rest)

                return shard_map(
                    inner, mesh=mesh,
                    in_specs=(P("data", "model", None),) * 4,
                    out_specs=(P("data", "model", None), P(), P(), P(), P(), P()),
                    check_vma=False,
                )(u, v, msk, x0)

            x0 = jax.ShapeDtypeStruct((G, G, e_cell), jnp.float32)
            shardings = (NamedSharding(mesh, P("data", "model", None)),) * 4
            lowered = jax.jit(single, in_shardings=shardings).lower(u, v, msk, x0)
        rec["lower_s"] = round(time.perf_counter() - t0, 2)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t1, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
    }
    hlo = analyze_hlo(compiled.as_text(), num_partitions=n_dev)
    rec["hlo"] = hlo.as_dict()
    # per-iteration model cost: 2 SpMVs (4 nnz flops each) + O(nnz) vector
    rec["model_flops"] = {"total": 5000 * 12.0 * 2 * m}
    rec["roofline"] = roofline_terms(hlo, n_devices=n_dev, model_flops=rec["model_flops"]["total"])
    rec["ok"] = True
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "pod2", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--loss-chunk", type=int, default=512)
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--no-shard-seq", action="store_true",
                    help="decode: replicate the KV cache seq dim instead of TP-sharding")
    ap.add_argument("--remat", default=None, choices=[None, "none", "full"])
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "pod2"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    if args.list:
        for a, s, m in cells:
            r = skip_reason(get(a), SHAPES[s])
            print(f"{a:20s} {s:12s} {m:7s} {'SKIP: '+r if r else 'run'}")
        return

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    if args.arch == "mwu-graph":
        for m in meshes:
            out = OUT_DIR / f"mwu-graph__match__{m}.json"
            print(f"=== mwu-graph / match / {m} ===", flush=True)
            try:
                rec = run_mwu_cell(m)
            except Exception as e:
                rec = {"arch": "mwu-graph", "mesh": m, "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"  FAILED: {rec['error'][:300]}", flush=True)
            out.write_text(json.dumps(rec, indent=1))
            if rec.get("ok"):
                r = rec["roofline"]
                print(f"  ok compile={rec['compile_s']}s compute={r['compute_s']:.3e}s "
                      f"memory={r['memory_s']:.3e}s collective={r['collective_s']:.3e}s "
                      f"bottleneck={r['bottleneck']}", flush=True)
        return
    for a, s, m in cells:
        tag = f"__{args.tag}" if args.tag else ""
        out = OUT_DIR / f"{a}__{s}__{m}{tag}.json"
        print(f"=== {a} / {s} / {m} ===", flush=True)
        try:
            rec = run_cell(a, s, m, loss_chunk=args.loss_chunk,
                           attn_chunk=args.attn_chunk, extra_tag=args.tag,
                           decode_shard_seq=not args.no_shard_seq,
                           remat=args.remat)
        except Exception as e:  # record failures: they are dry-run bugs
            rec = {
                "arch": a, "shape": s, "mesh": m, "ok": False,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
            print(f"  FAILED: {rec['error'][:300]}", flush=True)
        out.write_text(json.dumps(rec, indent=1))
        if rec.get("ok"):
            r = rec["roofline"]
            print(
                f"  ok lower={rec['lower_s']}s compile={rec['compile_s']}s "
                f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                f"collective={r['collective_s']:.3e}s bottleneck={r['bottleneck']}",
                flush=True,
            )
        elif rec.get("skipped"):
            print(f"  skipped: {rec['reason']}", flush=True)


if __name__ == "__main__":
    main()
