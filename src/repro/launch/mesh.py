"""Production mesh construction (multi-pod dry-run spec).

A FUNCTION, not a module constant: importing this module never touches
jax device state. The dry-run sets XLA_FLAGS before importing jax to
fabricate 512 host devices; real deployments get the same shapes from
actual TPU topology.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "sharding_for"]


def _make_mesh(shape, axes, devices=None):
    """jax.make_mesh across jax versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist on newer
    jax; older releases default every axis to Auto anyway, so omitting
    the kwarg is semantically identical there.

    ``devices`` selects an explicit device subset (e.g. the first
    ``pod * data`` of ``jax.devices()`` for a :class:`repro.dist.MeshPlan`
    smaller than the host); ``jax.make_mesh`` has no stable cross-version
    spelling for that, so a subset goes through ``jax.sharding.Mesh``
    directly (fine on host/CPU devices — the perf-aware reordering
    ``jax.make_mesh`` adds only matters on real TPU topologies).
    """
    if devices is not None:
        import numpy as np

        devs = np.asarray(devices, dtype=object).reshape(tuple(shape))
        return jax.sharding.Mesh(devs, tuple(axes))
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(
        tuple(shape), tuple(axes), axis_types=(axis_type.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) single pod (256 chips) or (2,16,16) two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes, devices=None):
    """Arbitrary mesh (tests use (1,1) / (2,2) / (2,4) host-device meshes)."""
    return _make_mesh(shape, axes, devices)


def sharding_for(mesh, spec_tree):
    """PartitionSpec pytree -> NamedSharding pytree on this mesh."""
    from jax.sharding import NamedSharding, PartitionSpec

    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, PartitionSpec),
    )
