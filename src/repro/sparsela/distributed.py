"""Distributed implicit incidence products inside shard_map (paper §5.2).

All functions here are *local* SPMD functions: they take the device's
shard and use named-axis collectives. Mesh axes: ("data", "model") form
the square G x G grid; device (i, j) holds edge cell (i, j) and the
vertex-block-i shard of every vertex vector (replicated along "model").

Communication per product (per device): one psum over each axis of a
(block,) vector + one grid-transpose ppermute — O(n/G) words, matching
the paper's 2-D layout analysis.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

__all__ = ["grid_transpose", "mx_local", "mtw_local", "vertex_psum_lse"]


def _grid_perm(G: int):
    """Flattened (data-major) permutation pairs for (i,j) -> (j,i)."""
    return [(i * G + j, j * G + i) for i in range(G) for j in range(G)]


def grid_transpose(x, G: int, axes=("data", "model")):
    """Send this device's value to its transposed grid position."""
    return lax.ppermute(x, axis_name=axes, perm=_grid_perm(G))


def mx_local(u_loc, v_loc, mask, x_loc, block: int, G: int, axes=("data", "model")):
    """y = M x with edge-sharded x. Returns the block-i shard of y
    (replicated along the model axis).

    u_loc/v_loc: (e_cell,) block-local endpoints; x_loc: (e_cell,).
    """
    xm = jnp.where(mask, x_loc, 0)
    pu = jnp.zeros((block,), x_loc.dtype).at[u_loc].add(xm)
    pv = jnp.zeros((block,), x_loc.dtype).at[v_loc].add(xm)
    pu = lax.psum(pu, axes[1])  # complete u-sums for row-block i
    pv = lax.psum(pv, axes[0])  # complete v-sums for col-block j
    pv_t = grid_transpose(pv, G, axes)  # now v-sums for block i
    return pu + pv_t


def mtw_local(u_loc, v_loc, mask, w_loc, G: int, axes=("data", "model")):
    """g = M^T w with vertex-sharded w (block i on row i, replicated on
    model). Returns the edge-cell shard of g.

    The row block w_i is resident; the column block w_j arrives via the
    grid transpose (the paper's row+column broadcast)."""
    w_col = grid_transpose(w_loc, G, axes)  # block j for this device
    g = w_loc[u_loc] + w_col[v_loc]
    return jnp.where(mask, g, 0)


def vertex_psum_lse(a_loc, axes=("data", "model")):
    """Stable distributed logsumexp over a vertex-sharded vector.

    a_loc: (block,) local slice (same value on every model rank).
    Returns (lse, local softmax numerator exp(a - m_global)); dividing by
    sum gives the global softmax restricted to the local block.
    """
    m_loc = jnp.max(a_loc)
    m = lax.pmax(m_loc, axes[0])  # model ranks replicate -> reduce data only
    e = jnp.exp(a_loc - m)
    s = lax.psum(jnp.sum(e), axes[0])
    return m + jnp.log(s), e, s
