"""2-D edge partition of the implicit incidence matrix (paper §5.2).

The vertex set is split into G contiguous blocks (G = grid side); edge
(u, v) belongs to grid cell (block(u), block(v)). Device (i, j) stores
its cell's edges with *block-local* endpoint indices, padded to the max
cell population (SPMD static shapes). With this layout:

    y = M x  : per-cell segment-sums -> psum(row) + psum(col) + transpose
    g = M^T w: w block arrives by row residency + grid transpose, then a
               pure local gather  w_i[u_loc] + w_j[v_loc]

Each device communicates O(n/G) words per product — the paper's bound.
Preprocessing is host-side numpy, once per graph (like the paper's
matrix assembly).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..graphs.graph import Graph

__all__ = ["Partition2D", "partition_edges", "partition_edges_1d"]


def partition_edges_1d(n_edges: int, parts: int) -> tuple[int, int]:
    """1-D contiguous edge-slab split (the ``repro.dist`` pod layout).

    Where :func:`partition_edges` builds the paper's 2-D grid layout
    (block-local endpoint ids, per-cell padding) for the legacy
    ``core.mwu_dist`` driver, the mesh-sharded solver keeps *global*
    endpoint ids and simply slabs the edge dimension across the ``pod``
    axis: device k owns edges ``[k * slab, (k + 1) * slab)`` of the
    (end-padded) edge list, and the vertex-space coupling is completed
    by one ``psum`` per matvec instead of grid transposes.

    Returns ``(padded_edge_count, slab_width)`` with
    ``padded_edge_count == parts * slab_width`` and
    ``slab_width == ceil(n_edges / parts)``; padding (masked edges)
    is appended at the global end, so a solution over the padded edge
    list strips back to the original with ``x[:n_edges]``.
    """
    parts = max(int(parts), 1)
    n_edges = max(int(n_edges), 1)
    slab = -(-n_edges // parts)
    return parts * slab, slab


@dataclass
class Partition2D:
    grid: int  # G (square grid side)
    n_pad: int  # padded vertex count (G * block)
    block: int  # vertices per block
    e_cell: int  # padded edges per cell
    # (G, G, e_cell) int32 block-local endpoint ids + validity mask
    u_loc: np.ndarray
    v_loc: np.ndarray
    mask: np.ndarray

    @property
    def shapes(self):
        return dict(grid=self.grid, block=self.block, e_cell=self.e_cell)


def partition_edges(g: Graph, grid: int, pad_factor: float = 1.0) -> Partition2D:
    """Assign each edge to cell (block(u), block(v)); pad cells equally."""
    block = (g.n + grid - 1) // grid
    n_pad = block * grid
    bu = (g.u // block).astype(np.int64)
    bv = (g.v // block).astype(np.int64)
    cell = bu * grid + bv
    order = np.argsort(cell, kind="stable")
    cell_sorted = cell[order]
    counts = np.bincount(cell_sorted, minlength=grid * grid)
    e_cell = int(max(8, np.ceil(counts.max() * max(pad_factor, 1.0))))

    u_loc = np.zeros((grid * grid, e_cell), np.int32)
    v_loc = np.zeros((grid * grid, e_cell), np.int32)
    mask = np.zeros((grid * grid, e_cell), bool)
    starts = np.zeros(grid * grid + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    us = (g.u[order] % block).astype(np.int32)
    vs = (g.v[order] % block).astype(np.int32)
    for c in range(grid * grid):
        s, e = starts[c], starts[c + 1]
        k = e - s
        u_loc[c, :k] = us[s:e]
        v_loc[c, :k] = vs[s:e]
        mask[c, :k] = True
    return Partition2D(
        grid=grid,
        n_pad=n_pad,
        block=block,
        e_cell=e_cell,
        u_loc=u_loc.reshape(grid, grid, e_cell),
        v_loc=v_loc.reshape(grid, grid, e_cell),
        mask=mask.reshape(grid, grid, e_cell),
    )
