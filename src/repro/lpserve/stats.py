"""Serving counters: what the batching engine actually bought you.

One :class:`BucketStats` per (problem family, bucket) pair, aggregated
by :func:`aggregate` into the flat dict ``LPEngine.stats()`` returns.
The numbers that matter operationally:

* ``batches`` vs ``requests`` — continuous batching is working iff
  batches ≪ requests x calls-per-request;
* ``lane_occupancy`` — fraction of launched lanes carrying a real
  request (the rest re-ran a duplicate to keep the XLA shape static);
* ``padding_waste`` — fraction of bucket edge slots spent on padding
  (bucket ladder tuning signal);
* ``compile_cache_hits`` — dispatches that reused an already-compiled
  shape; a healthy ladder compiles once per (family, bucket) and hits
  the cache forever after;
* ``latency_p50_s`` / ``latency_p99_s`` — submit-to-solution wall time.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["BucketStats", "aggregate"]


@dataclass
class BucketStats:
    """Counters for one (family, bucket) dispatch group."""

    family: str
    bucket: str
    requests: int = 0  # admitted
    completed: int = 0  # solutions delivered
    not_found: int = 0  # completed without a feasible certificate
    batches: int = 0  # solve_batch launches
    lane_rounds: int = 0  # lanes launched (batches x lane width)
    occupied_lane_rounds: int = 0  # lanes carrying a distinct live request
    feasibility_calls: int = 0  # real feasibility probes consumed
    mwu_iters: int = 0  # total MWU iterations across real lanes
    batch_seconds: float = 0.0  # wall time inside solve_batch
    compiles: int = 0  # dispatches that built a new XLA program
    compile_cache_hits: int = 0  # dispatches that reused one
    edge_slots_used: int = 0  # bucket edge capacity over occupied lanes
    real_edges_used: int = 0  # real edges over occupied lanes
    latencies_s: list[float] = field(default_factory=list)

    @property
    def lane_occupancy(self) -> float:
        return self.occupied_lane_rounds / self.lane_rounds if self.lane_rounds else 0.0

    @property
    def padding_waste(self) -> float:
        if not self.edge_slots_used:
            return 0.0
        return 1.0 - self.real_edges_used / self.edge_slots_used

    def latency_quantile(self, q: float) -> float:
        if not self.latencies_s:
            return float("nan")
        return float(np.percentile(np.asarray(self.latencies_s), q))

    def as_dict(self) -> dict:
        return {
            "family": self.family,
            "bucket": self.bucket,
            "requests": self.requests,
            "completed": self.completed,
            "not_found": self.not_found,
            "batches": self.batches,
            "lane_rounds": self.lane_rounds,
            "lane_occupancy": round(self.lane_occupancy, 4),
            "padding_waste": round(self.padding_waste, 4),
            "feasibility_calls": self.feasibility_calls,
            "mwu_iters": self.mwu_iters,
            "batch_seconds": round(self.batch_seconds, 4),
            "compiles": self.compiles,
            "compile_cache_hits": self.compile_cache_hits,
            "latency_p50_s": round(self.latency_quantile(50), 4),
            "latency_p99_s": round(self.latency_quantile(99), 4),
        }


def aggregate(buckets) -> dict:
    """Flatten per-bucket counters into the engine-level stats dict."""
    buckets = list(buckets)
    lat = [t for b in buckets for t in b.latencies_s]
    lane_rounds = sum(b.lane_rounds for b in buckets)
    occupied = sum(b.occupied_lane_rounds for b in buckets)
    slots = sum(b.edge_slots_used for b in buckets)
    real = sum(b.real_edges_used for b in buckets)
    return {
        "requests": sum(b.requests for b in buckets),
        "completed": sum(b.completed for b in buckets),
        "not_found": sum(b.not_found for b in buckets),
        "batches": sum(b.batches for b in buckets),
        "feasibility_calls": sum(b.feasibility_calls for b in buckets),
        "mwu_iters": sum(b.mwu_iters for b in buckets),
        "batch_seconds": round(sum(b.batch_seconds for b in buckets), 4),
        "lane_occupancy": round(occupied / lane_rounds, 4) if lane_rounds else 0.0,
        "padding_waste": round(1.0 - real / slots, 4) if slots else 0.0,
        "compiles": sum(b.compiles for b in buckets),
        "compile_cache_hits": sum(b.compile_cache_hits for b in buckets),
        "latency_p50_s": float(np.percentile(lat, 50)) if lat else float("nan"),
        "latency_p99_s": float(np.percentile(lat, 99)) if lat else float("nan"),
        "buckets": {f"{b.family}/{b.bucket}": b.as_dict() for b in buckets},
    }
