"""repro.lpserve — shape-bucketed continuous-batching serving for graph LPs.

The serving subsystem on top of :mod:`repro.api`: heterogeneous
:class:`~repro.api.Problem` requests are padded into shape buckets
(:mod:`.bucketing`), batched onto fixed lane slots, and driven through
``Solver.solve_batch`` one feasibility round at a time with continuous
lane refill (:mod:`.engine`); per-bucket serving counters come from
:mod:`.stats`. Typical use::

    from repro.lpserve import LPEngine, LPServeConfig
    from repro.graphs import build, erdos

    engine = LPEngine(LPServeConfig(lanes=8))
    rids = [engine.submit(build("match", erdos(50 * (i + 1), 140 * (i + 1), seed=i)))
            for i in range(16)]
    solutions = engine.run()          # {rid: Solution}
    print(engine.stats()["batches"])  # far fewer than feasibility calls
"""
from .bucketing import BucketPolicy, BucketSpec, pad_problem, pad_problems, problem_dims
from .engine import BoundSearch, LPEngine, LPServeConfig
from .stats import BucketStats, aggregate

__all__ = [
    "BucketPolicy",
    "BucketSpec",
    "pad_problem",
    "pad_problems",
    "problem_dims",
    "BoundSearch",
    "LPEngine",
    "LPServeConfig",
    "BucketStats",
    "aggregate",
]
