"""Continuous-batching LP engine: the graph-LP analogue of serve/engine.py.

``serve/engine.py`` batches LM decode across fixed slots; here the unit
of work is one *feasibility probe* of one request's bound search. Each
request is a declarative :class:`~repro.api.Problem`; its binary search
is unrolled into an incremental :class:`BoundSearch` state machine so
the engine can interleave many searches:

1. ``submit`` pads the problem into its shape bucket
   (:mod:`.bucketing`) and enqueues it under a ``(family, bucket)``
   dispatch key;
2. each ``step`` picks the busiest key, refills that bucket's fixed
   lane slots from the queue (continuous batching — free lanes are
   refilled every round, no waiting for a full batch), collects every
   active request's next probe bound, and launches ONE
   ``Solver.solve_batch`` across the stacked lanes;
3. lane results are unpadded back to original variables and fed to each
   request's search; finished requests certify into per-request
   :class:`~repro.api.Solution`s and free their lane.

Because every launch under a dispatch key has identical shapes (slot
count is static; unused lanes re-run a duplicate), XLA compiles once
per key and the jit cache serves every subsequent round —
``stats()["compile_cache_hits"]`` proves it.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..api.problem import Problem
from ..api.solver import (
    Solution,
    Solver,
    certify_solution,
    feasibility_solution,
    not_found_solution,
    stack_problems,
)
from ..core.mwu import MWUOptions, MWUResult, Status
from ..dist.mesh import MeshPlan
from ..dist.solver import DistSolver
from .bucketing import BucketPolicy, BucketSpec, pad_problem, problem_dims
from .stats import BucketStats, aggregate

__all__ = ["LPServeConfig", "LPEngine", "BoundSearch"]


@dataclass(frozen=True)
class LPServeConfig:
    """Engine knobs (frozen so a config can key caches/logs).

    ``mesh`` (a :class:`repro.dist.MeshPlan`, optional) shards each
    dispatch across the device mesh: lane slots fan out over the
    ``data`` axis and each lane's variable space slabs over ``pod``.
    ``None`` keeps the single-device ``Solver`` path bit-for-bit.
    """

    opts: MWUOptions = field(default_factory=MWUOptions)
    lanes: int = 8  # batch slots per dispatch key
    policy: BucketPolicy = field(default_factory=BucketPolicy)
    rel_tol: float | None = None  # bound-search granularity (default eps/2)
    max_calls: int = 64  # per-request feasibility budget
    pad_lanes: bool = True  # always launch the full slot count (shape-static)
    mesh: MeshPlan | None = None  # shard lane slots across this mesh

    def __post_init__(self):
        if self.lanes < 1:
            raise ValueError("lanes must be >= 1")
        if self.mesh is not None and self.lanes % self.mesh.data != 0:
            raise ValueError(
                f"lanes ({self.lanes}) must be a multiple of the mesh data "
                f"axis ({self.mesh.data}) so lane slots shard evenly"
            )


class BoundSearch:
    """Incremental port of ``Solver._bound_search`` (one probe per round).

    ``next_bound`` yields the bound this request wants evaluated;
    ``update`` consumes the (unpadded) feasibility result and advances
    the bracket. ``solution`` is set exactly when the search finishes,
    built by the same certification helpers the sequential solver uses,
    so engine answers are bit-compatible with ``Solver.solve`` at
    ``batch_width=1``.
    """

    def __init__(self, problem: Problem, rel_tol: float, max_calls: int):
        self.problem = problem
        self.rel = rel_tol
        self.max_calls = max_calls
        self.stats = {"calls": 0, "iters": 0, "probes": 0}
        self.best: MWUResult | None = None
        self.best_bound: float | None = None
        self.solution: Solution | None = None
        self.lo = float(problem.lo) if problem.bound_mode != "none" else 0.0
        self.hi = float(problem.hi) if problem.bound_mode != "none" else 0.0
        self.is_max = problem.feasible_side == "lo"
        if problem.bound_mode == "none":
            self.phase = "single"
        elif self.is_max:
            self.phase = "bisect"
            self._maybe_finish()
        else:
            # min-like senses check the easy endpoint first (cheap
            # not-found exit, mirroring the legacy drivers)
            self.phase = "endpoint"

    @property
    def done(self) -> bool:
        return self.solution is not None

    def _bracket_open(self) -> bool:
        return (
            self.hi / max(self.lo, 1e-300) > 1.0 + self.rel
            and self.stats["calls"] < self.max_calls
        )

    def next_bound(self) -> float:
        assert not self.done, "search already finished"
        if self.phase == "single":
            return 1.0  # ignored by bound_mode="none" instantiation
        if self.phase == "endpoint":
            return self.hi
        if self.phase == "final_lo":
            return self.lo
        # geometric midpoint, written exactly as Solver._bound_search's
        # K=1 probe (lo * r ** (1/2)) so probe sequences are bit-identical
        return self.lo * (self.hi / max(self.lo, 1e-300)) ** 0.5

    def update(self, bound: float, res: MWUResult) -> None:
        assert not self.done, "search already finished"
        ok = int(res.status) == Status.FEASIBLE
        st = self.stats
        st["calls"] += 1
        st["iters"] += int(res.iters)
        st["probes"] += int(res.ls_probes)

        if self.phase == "single":
            self.solution = feasibility_solution(self.problem, res, st)
            return
        if self.phase == "endpoint":
            if not ok:
                self.solution = not_found_solution(self.problem, self.hi, res, st)
                return
            self.best, self.best_bound = res, self.hi
            self.phase = "bisect"
            self._maybe_finish()
            return
        if self.phase == "final_lo":
            if ok:
                self.solution = certify_solution(self.problem, res, self.lo, st)
            else:
                self.solution = not_found_solution(self.problem, self.lo, res, st)
            return
        # bisect: shrink the bracket toward the feasible side
        if self.is_max:
            if ok:
                self.lo, self.best, self.best_bound = bound, res, bound
            else:
                self.hi = bound
        else:
            if ok:
                self.hi, self.best, self.best_bound = bound, res, bound
            else:
                self.lo = bound
        self._maybe_finish()

    def _maybe_finish(self) -> None:
        if self._bracket_open():
            return
        if self.best is None:
            # only reachable for max-sense: lo itself was never probed
            self.phase = "final_lo"
            return
        self.solution = certify_solution(
            self.problem, self.best, self.best_bound, self.stats
        )


@dataclass
class _Request:
    rid: int
    problem: Problem  # original (unpadded) spec
    padded: Problem
    bucket: BucketSpec
    search: BoundSearch
    t_submit: float
    t_done: float | None = None


class _BucketState:
    """Live state of one (family, bucket) dispatch key."""

    def __init__(self, family: str, bucket: BucketSpec):
        self.bucket = bucket
        self.queue: deque[_Request] = deque()
        self.active: list[_Request] = []
        self.stats = BucketStats(family=family, bucket=str(bucket))

    @property
    def backlog(self) -> int:
        return len(self.queue) + len(self.active)


def _jit_cache_size() -> int | None:
    """Entries in the batched-feasibility jit cache (None if unreadable)."""
    from ..api import solver as _solver

    try:
        return int(_solver._feasibility_batch._cache_size())
    except Exception:
        return None


class LPEngine:
    """Shape-bucketed continuous-batching server for graph-LP requests."""

    def __init__(self, config: LPServeConfig | None = None):
        self.cfg = config if config is not None else LPServeConfig()
        if self.cfg.mesh is not None:
            self.solver: Solver = DistSolver(
                self.cfg.opts,
                plan=self.cfg.mesh,
                batch_width=1,
                max_calls=self.cfg.max_calls,
            )
        else:
            self.solver = Solver(self.cfg.opts, batch_width=1, max_calls=self.cfg.max_calls)
        self.rel_tol = (
            self.cfg.rel_tol if self.cfg.rel_tol is not None else self.cfg.opts.eps / 2
        )
        self._buckets: dict[tuple, _BucketState] = {}
        self._done: dict[int, Solution] = {}
        self._requests: dict[int, _Request] = {}
        self._next_rid = 0
        self._seen_shapes: set[tuple] = set()

    # ---------------------------------------------------------- intake --
    def _dispatch_key(self, prob: Problem, bucket: BucketSpec) -> tuple:
        return (prob.name, prob.kind, prob.sense, prob.bound_mode, bucket)

    def submit(self, problem: Problem) -> int:
        """Enqueue one request; returns its request id."""
        n, m = problem_dims(problem)
        bucket = self.cfg.policy.bucket_for(n, m)
        padded = pad_problem(problem, bucket)
        key = self._dispatch_key(problem, bucket)
        state = self._buckets.get(key)
        if state is None:
            state = self._buckets[key] = _BucketState(problem.name, bucket)
        rid = self._next_rid
        self._next_rid += 1
        req = _Request(
            rid=rid,
            problem=problem,
            padded=padded,
            bucket=bucket,
            search=BoundSearch(problem, self.rel_tol, self.cfg.max_calls),
            t_submit=time.perf_counter(),
        )
        self._requests[rid] = req
        state.queue.append(req)
        state.stats.requests += 1
        # a request can be born finished (degenerate bracket, zero probes)
        if req.search.done:
            state.queue.pop()
            self._finish(state, req)
        return rid

    # -------------------------------------------------------- dispatch --
    def _pick_bucket(self) -> _BucketState | None:
        busiest = None
        for state in self._buckets.values():
            if state.backlog and (busiest is None or state.backlog > busiest.backlog):
                busiest = state
        return busiest

    def _finish(self, state: _BucketState, req: _Request) -> None:
        req.t_done = time.perf_counter()
        sol = req.search.solution
        self._done[req.rid] = sol
        state.stats.completed += 1
        state.stats.latencies_s.append(req.t_done - req.t_submit)
        if not sol.found:
            state.stats.not_found += 1

    def step(self) -> bool:
        """One dispatch round on the busiest bucket; False when idle."""
        state = self._pick_bucket()
        if state is None:
            return False
        # continuous batching: refill free lanes from the queue
        while len(state.active) < self.cfg.lanes and state.queue:
            state.active.append(state.queue.popleft())

        real = [(req, req.search.next_bound()) for req in state.active]
        lanes = list(real)
        if self.cfg.pad_lanes:
            while len(lanes) < self.cfg.lanes:  # idle lanes re-run a live probe
                lanes.append(lanes[len(lanes) % len(real)])

        shape_key = (
            self._dispatch_key(lanes[0][0].problem, state.bucket),
            len(lanes),
        )
        # mesh-sharded launches go through repro.dist's own callable
        # cache, not _feasibility_batch's — use the shape-key heuristic.
        cache0 = _jit_cache_size() if self.cfg.mesh is None else None

        stacked = stack_problems([req.padded for req, _ in lanes])
        bounds = jnp.asarray([b for _, b in lanes])
        t0 = time.perf_counter()
        batch = self.solver.solve_batch(stacked, bounds, batched_problem=True)
        jax.block_until_ready(batch.x)
        dt = time.perf_counter() - t0

        cache1 = _jit_cache_size() if self.cfg.mesh is None else None
        if cache0 is not None and cache1 is not None:
            hit = cache1 == cache0
        else:
            hit = shape_key in self._seen_shapes
        self._seen_shapes.add(shape_key)

        st = state.stats
        st.batches += 1
        st.batch_seconds += dt
        st.lane_rounds += len(lanes)
        st.occupied_lane_rounds += len(real)
        st.feasibility_calls += len(real)
        st.compile_cache_hits += int(hit)
        st.compiles += int(not hit)
        for req, _ in real:
            _, m = problem_dims(req.problem)
            st.edge_slots_used += state.bucket.n_edges
            st.real_edges_used += m

        for j, (req, bound) in enumerate(real):
            lane = jax.tree.map(lambda a: a[j], batch)
            res = lane._replace(x=np.asarray(lane.x)[: req.problem.n_vars])
            st.mwu_iters += int(res.iters)
            req.search.update(bound, res)
            if req.search.done:
                self._finish(state, req)
        state.active = [r for r in state.active if not r.search.done]
        return True

    # ------------------------------------------------------- inspection --
    def audit_launches(self) -> dict[tuple, tuple[Problem, jnp.ndarray]]:
        """The (stacked problem, bounds) each dispatch key would launch next.

        For every bucket with backlog, assembles the lanes exactly like
        :meth:`step` — refill simulation, live probe bounds, idle-lane
        duplication, :func:`stack_problems` — WITHOUT mutating any
        engine state (queues, searches and stats are untouched), so
        ``repro.tracecheck`` can lower and lint the real per-key
        programs of a loaded engine. Keyed by the same ``(name, kind,
        sense, bound_mode, bucket)`` dispatch key the jit cache sees.
        """
        out: dict[tuple, tuple[Problem, jnp.ndarray]] = {}
        for key, state in self._buckets.items():
            would_be_active = list(state.active)
            backlog = list(state.queue)
            while len(would_be_active) < self.cfg.lanes and backlog:
                would_be_active.append(backlog.pop(0))
            real = [(req, req.search.next_bound()) for req in would_be_active]
            if not real:
                continue
            lanes = list(real)
            if self.cfg.pad_lanes:
                while len(lanes) < self.cfg.lanes:
                    lanes.append(lanes[len(lanes) % len(real)])
            stacked = stack_problems([req.padded for req, _ in lanes])
            bounds = jnp.asarray([b for _, b in lanes])
            out[key] = (stacked, bounds)
        return out

    # ------------------------------------------------------------ sync --
    def run(self) -> dict[int, Solution]:
        """Drain every pending request; returns {rid: Solution}."""
        while self.step():
            pass
        return dict(self._done)

    def solve_many(self, problems: list[Problem]) -> list[Solution]:
        """Submit + drain a batch; Solutions in submission order."""
        rids = [self.submit(p) for p in problems]
        self.run()
        return [self._done[r] for r in rids]

    def result(self, rid: int) -> Solution | None:
        return self._done.get(rid)

    def stats(self) -> dict:
        """Aggregated serving counters (see :mod:`repro.lpserve.stats`).

        With a mesh-sharded config the dict gains a ``"mesh"`` section:
        the plan shape, per-device lane occupancy (lane rounds divided
        across the ``data`` axis), and the distributed solver's launch /
        psum-round counters.
        """
        out = aggregate(s.stats for s in self._buckets.values())
        plan = self.cfg.mesh
        if plan is not None:
            lane_rounds = sum(s.stats.lane_rounds for s in self._buckets.values())
            ds = dict(self.solver.dist_stats)
            out["mesh"] = {
                "pod": plan.pod,
                "data": plan.data,
                "devices": plan.n_devices,
                "lanes_per_device": self.cfg.lanes // plan.data,
                "lane_rounds_per_device": lane_rounds // plan.data,
                "dist_launches": ds["launches"],
                "psum_rounds": ds["psum_rounds"],
            }
        return out
