"""Shape buckets: pad heterogeneous graph LPs onto shared compiled shapes.

XLA compiles one program per input shape, so a serving engine that
accepted every ``(n_vertices, n_edges)`` verbatim would recompile the
MWU ``lax.while_loop`` for every new graph size. The classic fix
(serve/engine.py's slot batching for LMs) is shape bucketing: round
request shapes up to a small ladder of bucket sizes, pad the request
into its bucket, and batch requests that share a bucket.

For graph LPs the padding is *masked*, not merely zeroed:

* padded edges get ``edge_mask=False`` in the implicit operators, so
  they vanish from every matvec/rmatvec/colmax;
* padded constraint rows are excluded from the smoothed potentials via
  ``p_mask``/``c_mask`` (otherwise an all-zero covering row would make
  every padded problem infeasible);
* padded objective entries are zero, so certificates and objectives are
  computed over real variables only.

Together these guarantee *padding parity*: the padded LP has exactly
the same feasible set over real variables as the original, so the
certified objective agrees with the unpadded solve within the usual
(1+eps) band (tests/test_lpserve.py proves it per problem family).

``pad_problems`` output feeds straight into
:func:`repro.api.stack_problems` — problems padded into the same bucket
share every leaf shape and all static metadata.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp

from ..api.problem import Problem
from ..core.operators import (
    AdjacencyPlusId,
    Coo,
    Incidence,
    InterweavedId,
    LinOp,
    ScaledRows,
    Transposed,
    VertexEdgePair,
    VStack,
)

__all__ = ["BucketSpec", "BucketPolicy", "problem_dims", "pad_problem", "pad_problems"]


@dataclass(frozen=True)
class BucketSpec:
    """One compiled shape: every request padded here shares one XLA program."""

    n_vertices: int
    n_edges: int

    def __str__(self):
        return f"V{self.n_vertices}xE{self.n_edges}"


@dataclass(frozen=True)
class BucketPolicy:
    """Rounds request dims up to bucket dims.

    Explicit ladders (``vertex_sizes`` / ``edge_sizes``) win when given;
    otherwise dims round up to ``floor * growth^k`` (geometric ladder,
    default power-of-two above a floor) so the number of distinct
    compiled shapes stays logarithmic in the size spread.
    """

    vertex_sizes: tuple[int, ...] | None = None
    edge_sizes: tuple[int, ...] | None = None
    vertex_floor: int = 64
    edge_floor: int = 256
    growth: float = 2.0

    def __post_init__(self):
        if self.growth <= 1.0:
            raise ValueError("growth must be > 1")
        for ladder in (self.vertex_sizes, self.edge_sizes):
            if ladder is not None and tuple(sorted(ladder)) != tuple(ladder):
                raise ValueError(f"bucket ladder must be sorted, got {ladder}")

    @staticmethod
    def _round_up(x: int, ladder, floor: int, growth: float) -> int:
        if ladder is not None:
            for size in ladder:
                if x <= size:
                    return int(size)
            raise ValueError(
                f"request dim {x} exceeds the largest configured bucket {ladder[-1]}"
            )
        if x <= floor:
            return int(floor)
        k = math.ceil(math.log(x / floor) / math.log(growth))
        # float log can land one rung high/low; snap to the smallest rung >= x
        while floor * growth ** (k - 1) >= x:
            k -= 1
        while floor * growth**k < x:
            k += 1
        return int(math.ceil(floor * growth**k))

    def bucket_for(self, n_vertices: int, n_edges: int) -> BucketSpec:
        return BucketSpec(
            n_vertices=self._round_up(
                n_vertices, self.vertex_sizes, self.vertex_floor, self.growth
            ),
            n_edges=self._round_up(n_edges, self.edge_sizes, self.edge_floor, self.growth),
        )


# ------------------------------------------------------------------ dims --
def _op_dims(op: LinOp):
    """(n_vertices | None, n_edges | None) implied by one operator."""
    if isinstance(op, Incidence):
        return op.n_vertices, int(op.u.shape[0])
    if isinstance(op, (AdjacencyPlusId, VertexEdgePair)):
        return op.n_vertices, int(op.u.shape[0])
    if isinstance(op, InterweavedId):
        return None, op.n_edges
    if isinstance(op, Transposed):
        return _op_dims(op.inner)
    if isinstance(op, ScaledRows):
        return _op_dims(op.inner)
    if isinstance(op, VStack):
        n = m = None
        for o in op.ops:
            on, om = _op_dims(o)
            n = on if n is None else n
            m = om if m is None else m
        return n, m
    return None, None  # Coo / Dense carry no graph dims of their own


def problem_dims(prob: Problem) -> tuple[int, int]:
    """(n_vertices, n_edges) of the graph behind a builder Problem."""
    if prob.graph is not None:
        return int(prob.graph.n), int(prob.graph.m)
    n = m = None
    for op in (prob.P, prob.C):
        if op is None:
            continue
        on, om = _op_dims(op)
        n = on if n is None else n
        m = om if m is None else m
    if n is None or m is None:
        raise ValueError(
            f"problem {prob.name!r}: cannot infer (n_vertices, n_edges) from "
            "its operators; attach the source Graph or use graph-implicit ops"
        )
    return int(n), int(m)


# --------------------------------------------------------------- padding --
def _pad1(arr, length: int, fill):
    a = jnp.asarray(arr)
    extra = length - int(a.shape[0])
    if extra < 0:
        raise ValueError(f"cannot pad array of length {a.shape[0]} down to {length}")
    if extra == 0:
        return a
    return jnp.concatenate([a, jnp.full((extra,), fill, a.dtype)])


def _pad_edge_mask(old_mask, m_old: int, E: int):
    """Bucket edge mask: real edges keep their (optional) old mask, pads are off."""
    if old_mask is None:
        return jnp.arange(E) < m_old
    return _pad1(jnp.asarray(old_mask, bool), E, False)


def _pad_op(op: LinOp, N: int, E: int) -> LinOp:
    """Pad one operator onto bucket dims (padded entries fully masked)."""
    if isinstance(op, Incidence):
        return Incidence(
            u=_pad1(op.u, E, 0),
            v=_pad1(op.v, E, 0),
            n_vertices=N,
            weights=None if op.weights is None else _pad1(op.weights, E, 0),
            edge_mask=_pad_edge_mask(op.edge_mask, int(op.u.shape[0]), E),
        )
    if isinstance(op, AdjacencyPlusId):
        return AdjacencyPlusId(
            u=_pad1(op.u, E, 0),
            v=_pad1(op.v, E, 0),
            n_vertices=N,
            edge_mask=_pad_edge_mask(op.edge_mask, int(op.u.shape[0]), E),
        )
    if isinstance(op, VertexEdgePair):
        return VertexEdgePair(
            u=_pad1(op.u, E, 0),
            v=_pad1(op.v, E, 0),
            n_vertices=N,
            edge_mask=_pad_edge_mask(op.edge_mask, int(op.u.shape[0]), E),
        )
    if isinstance(op, InterweavedId):
        return InterweavedId(
            n_edges=E, edge_mask=_pad_edge_mask(op.edge_mask, op.n_edges, E)
        )
    if isinstance(op, Transposed):
        return Transposed(_pad_op(op.inner, N, E))
    if isinstance(op, ScaledRows):
        inner = _pad_op(op.inner, N, E)
        grow = inner.shape[0] - op.inner.shape[0]
        # padded rows are masked out of the potentials; scale 1 keeps them finite
        return ScaledRows(scale=_pad1(op.scale, int(op.scale.shape[0]) + grow, 1.0), inner=inner)
    if isinstance(op, VStack):
        return VStack(ops=tuple(_pad_op(o, N, E) for o in op.ops))
    if isinstance(op, Coo):
        r, c = op.shape
        # The only builder Coo is the edge-indexed x<=1 box (E x E identity);
        # padded entries carry val 0 per the Coo padding contract.
        if r != c:
            raise NotImplementedError("pad_problem: only square (edge-box) Coo supported")
        return Coo(
            rows=_pad1(op.rows, E, 0),
            cols=_pad1(op.cols, E, 0),
            vals=_pad1(op.vals, E, 0),
            _shape=(E, E),
        )
    raise NotImplementedError(f"pad_problem: no padding rule for {type(op).__name__}")


def _row_mask(op: LinOp, vm, em):
    """Bool mask of *real* rows of a padded operator."""
    if isinstance(op, (Incidence, AdjacencyPlusId, VertexEdgePair)):
        return vm
    if isinstance(op, InterweavedId):
        return em
    if isinstance(op, Transposed):
        return _col_mask(op.inner, vm, em)
    if isinstance(op, ScaledRows):
        return _row_mask(op.inner, vm, em)
    if isinstance(op, VStack):
        return jnp.concatenate([_row_mask(o, vm, em) for o in op.ops])
    if isinstance(op, Coo):
        return em  # edge-box rows
    raise NotImplementedError(f"row mask for {type(op).__name__}")


def _col_mask(op: LinOp, vm, em):
    """Bool mask of *real* columns (variables) of a padded operator."""
    if isinstance(op, Incidence):
        return em
    if isinstance(op, AdjacencyPlusId):
        return vm
    if isinstance(op, (VertexEdgePair, InterweavedId)):
        return jnp.repeat(em, 2)
    if isinstance(op, Transposed):
        return _row_mask(op.inner, vm, em)
    if isinstance(op, ScaledRows):
        return _col_mask(op.inner, vm, em)
    if isinstance(op, VStack):
        return _col_mask(op.ops[0], vm, em)
    if isinstance(op, Coo):
        return em  # edge-box columns
    raise NotImplementedError(f"col mask for {type(op).__name__}")


def unpad_slice(prob: Problem, padded: Problem) -> slice:
    """Slice selecting the original variables from a padded solution vector.

    Every padding rule appends at the end, and the densest-subgraph
    variable layout is interleaved per edge, so real variables are
    always the prefix.
    """
    return slice(0, int(prob.n_vars))


def pad_problem(prob: Problem, bucket: BucketSpec) -> Problem:
    """Pad ``prob`` onto ``bucket`` dims with full mask bookkeeping.

    The result shares pytree structure, leaf shapes and static metadata
    with every other same-family problem padded into ``bucket``, so
    :func:`repro.api.stack_problems` accepts the mix and one compiled
    ``solve_batch`` shape serves them all.
    """
    if prob.bound_mode == "callable":
        raise ValueError(
            f"problem {prob.name!r}: bound_mode='callable' closures cannot be "
            "padded/stacked; declare the bound through an array leaf instead"
        )
    n_old, m_old = problem_dims(prob)
    N, E = bucket.n_vertices, bucket.n_edges
    if n_old > N or m_old > E:
        raise ValueError(
            f"problem {prob.name!r} with dims ({n_old}, {m_old}) does not fit "
            f"bucket {bucket}"
        )
    vm = jnp.arange(N) < n_old
    em = jnp.arange(E) < m_old

    P = None if prob.P is None else _pad_op(prob.P, N, E)
    C = None if prob.C is None else _pad_op(prob.C, N, E)

    def grown_mask(old, op_pad):
        derived = _row_mask(op_pad, vm, em)
        if old is None:
            return derived
        return _pad1(jnp.asarray(old, bool), int(derived.shape[0]), False)

    p_mask = None if P is None else grown_mask(prob.p_mask, P)
    c_mask = None if C is None else grown_mask(prob.c_mask, C)

    ref = P if P is not None else C
    n_vars = int(ref.shape[1])
    c = None if prob.c is None else _pad1(prob.c, n_vars, 0)
    nnz = sum(op.nnz for op in (P, C) if op is not None)
    return Problem(
        name=prob.name,
        kind=prob.kind,
        sense=prob.sense,
        bound_mode=prob.bound_mode,
        P=P,
        C=C,
        c=c,
        p_mask=p_mask,
        c_mask=c_mask,
        lo=prob.lo,
        hi=prob.hi,
        n_vars=n_vars,
        nnz=nnz,
        graph=prob.graph,
    )


def pad_problems(probs: list[Problem], policy: BucketPolicy | None = None,
                 bucket: BucketSpec | None = None) -> tuple[list[Problem], BucketSpec]:
    """Pad a mixed-size batch into one shared bucket.

    The bucket is ``bucket`` when given, else the policy bucket of the
    largest dims in the batch. Returns (padded problems, bucket) ready
    for :func:`repro.api.stack_problems`.
    """
    if not probs:
        raise ValueError("pad_problems: need at least one problem")
    if bucket is None:
        policy = policy if policy is not None else BucketPolicy()
        dims = [problem_dims(p) for p in probs]
        bucket = policy.bucket_for(max(n for n, _ in dims), max(m for _, m in dims))
    return [pad_problem(p, bucket) for p in probs], bucket


def padding_waste(prob: Problem, bucket: BucketSpec) -> float:
    """Fraction of bucket edge slots wasted on padding for this problem."""
    _, m_old = problem_dims(prob)
    return 1.0 - m_old / max(bucket.n_edges, 1)
