"""repro: MWU positive-LP solving (Ju et al., CS.DC 2023) as a multi-pod
JAX framework. See DESIGN.md for the system inventory."""

__version__ = "1.0.0"
