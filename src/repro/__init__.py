"""repro: MWU positive-LP solving (Ju et al., CS.DC 2023) as a multi-pod
JAX framework. See DESIGN.md for the system inventory.

Layers: :mod:`repro.core` (MWU feasibility kernel + implicit operators),
:mod:`repro.graphs` (graph generators and declarative LP builders),
:mod:`repro.api` (the ``Problem``/``Solver`` facade), and
:mod:`repro.lpserve` (shape-bucketed continuous-batching serving engine
for heterogeneous graph-LP request traffic).
"""

__version__ = "1.0.0"
