"""Sharded npz checkpointing with atomic manifest commit + async save.

Layout:  <dir>/step_<N>/shard_<p>.npz + manifest.json (written LAST,
atomically via rename) — a partially-written checkpoint is never
restorable, and restore picks the newest step with a valid manifest.
``save_async`` offloads serialization to a worker thread so the train
loop only blocks on the previous save (one-deep pipeline), mirroring
production async checkpointing.

On a real multi-host pod each process writes its local shard_<p>; here
process 0 writes everything (single-host CPU), but the format and the
commit protocol are the multi-host ones.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np

__all__ = ["save", "save_async", "restore_latest", "latest_step", "wait_pending"]

_pending: list[threading.Thread] = []


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir, step: int, tree, process_index: int = 0, keep: int = 3):
    ckpt_dir = Path(ckpt_dir)
    stage = ckpt_dir / f"_staging_step_{step}"
    final = ckpt_dir / f"step_{step}"
    stage.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(stage / f"shard_{process_index}.npz", **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "time": time.time(),
        "shards": [f"shard_{process_index}.npz"],
    }
    (stage / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(stage, final)  # atomic commit
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(
        (int(p.name.split("_")[1]), p)
        for p in ckpt_dir.glob("step_*")
        if (p / "manifest.json").exists()
    )
    for _, p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def save_async(ckpt_dir, step: int, tree, keep: int = 3):
    """Snapshot to host memory now; write in a worker thread."""
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
    wait_pending()  # one-deep pipeline
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree), kwargs={"keep": keep})
    t.start()
    _pending.append(t)
    return t


def wait_pending():
    while _pending:
        _pending.pop().join()


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in ckpt_dir.glob("step_*")
        if (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def restore_latest(ckpt_dir, like_tree):
    """Restore newest valid checkpoint into the structure of ``like_tree``.

    Returns (tree, step) or (None, None) when no checkpoint exists.
    """
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    d = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = {}
    for shard in manifest["shards"]:
        with np.load(d / shard) as z:
            data.update({k: z[k] for k in z.files})
    leaves, treedef = _flatten(like_tree)
    assert manifest["n_leaves"] == len(leaves), "checkpoint/model structure mismatch"
    new_leaves = [
        np.asarray(data[f"leaf_{i}"]).astype(np.asarray(l).dtype) for i, l in enumerate(leaves)
    ]
    return jax.tree.unflatten(treedef, new_leaves), step
