"""Deterministic, stateless-seekable synthetic token pipeline.

``batch_at(seed, step)`` is a pure function -> restarts after failure
reproduce the exact stream (fault-tolerance invariant; DESIGN.md §5).
The generator mixes a per-(step, position) hash into token ids and packs
multiple short "documents" per sequence with EOS separators so the CE
loss has realistic structure (not uniform noise).
"""
from __future__ import annotations

import numpy as np

__all__ = ["TokenPipeline"]


class TokenPipeline:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int, seed: int = 0,
                 mean_doc_len: int = 256, eos_id: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        self.mean_doc = mean_doc_len
        self.eos = eos_id

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        B, S = self.batch, self.seq
        # Markov-ish stream: next token depends on previous through a
        # per-batch random linear congruence => learnable structure.
        a = rng.integers(1, self.vocab - 1, size=(B, 1), dtype=np.int64) | 1
        c = rng.integers(0, self.vocab - 1, size=(B, 1), dtype=np.int64)
        noise = rng.integers(0, self.vocab, size=(B, S), dtype=np.int64)
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = noise[:, 0]
        for t in range(1, S):
            det = (a[:, 0] * toks[:, t - 1] + c[:, 0]) % self.vocab
            use_noise = (noise[:, t] % 17) == 0  # ~6% noise
            toks[:, t] = np.where(use_noise, noise[:, t], det)
        # document breaks
        n_docs = max(1, S // self.mean_doc)
        for _ in range(n_docs):
            pos = rng.integers(0, S, size=B)
            toks[np.arange(B), pos] = self.eos
        tokens = toks.astype(np.int32)
        targets = np.roll(tokens, -1, axis=1)
        mask = np.ones((B, S), np.float32)
        mask[:, -1] = 0.0  # no target for the last position
        return {"tokens": tokens, "targets": targets, "loss_mask": mask}
