"""Rule/report framework + the solver's performance-invariant rule set.

A :class:`TraceArtifact` is one captured entry point (jaxpr, optionally
compiled HLO, plus the static context it was traced under: kernel
policy, solver options, mesh plan) with a dict of *expectations*
computed at capture time. A :class:`Rule` inspects one artifact and
yields :class:`Finding`s; :func:`run_rules` applies the default rule set.

The shipped rules (each guards one way the paper's per-iteration cost
model silently regresses):

``no-callbacks-in-loop``  no host callbacks / transfers inside the MWU
                          ``while`` (jaxpr prims + HLO custom-call
                          targets); traced artifacts must instead
                          contain their ``io_callback``.
``kernel-path``           ``pallas_call`` present in the loop exactly
                          when the resolved :class:`KernelPolicy` says
                          the kernel pack is active (and with the
                          matching interpret flag), absent under xla
                          and on vmapped lanes (custom_vmap batch rule).
``loop-collectives``      collective count/kind inside the loop body ==
                          the declared pod plan (two ``psum`` + one
                          ``pmax`` per iteration for pod-sharded plans,
                          none for identity plans).
``dtype-discipline``      no f64 avals / weak-type promotions beyond
                          the problem dtype (Python scalar closures are
                          the usual leak).
``trip-count``            the top-level ``while`` trip bound recovered
                          from compiled HLO == ``MWUOptions.max_iter``.
``vmem-footprint``        per-kernel VMEM block footprint (BlockSpecs:
                          resident blocks + double-buffered streaming
                          tiles) within the dispatch layer's budget.

Adding a rule: subclass :class:`Rule`, implement ``check(artifact)``,
append an instance to :data:`DEFAULT_RULES`. Give repeated findings a
stable ``key`` so one baseline entry (see :mod:`.report`) covers them.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax.numpy as jnp

from . import hlo_ir
from .jaxpr_scan import CALLBACK_PRIMS, COLLECTIVE_PRIMS, count_primitives, find_eqns

__all__ = [
    "ERROR",
    "WARNING",
    "Finding",
    "TraceArtifact",
    "Rule",
    "DEFAULT_RULES",
    "run_rules",
]

ERROR = "error"
WARNING = "warning"


@dataclass
class Finding:
    """One rule violation on one artifact.

    ``fingerprint`` identifies the violation *class* stably across runs
    (no counts or op names that drift with compiler versions), so a
    baseline allowlist entry keeps covering it.
    """

    rule: str
    severity: str
    artifact: str
    message: str
    key: str = ""
    detail: dict = field(default_factory=dict)

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}::{self.artifact}" + (f"::{self.key}" if self.key else "")

    def as_dict(self) -> dict:
        return {
            "rule": self.rule,
            "severity": self.severity,
            "artifact": self.artifact,
            "message": self.message,
            "fingerprint": self.fingerprint,
            "detail": dict(self.detail),
        }


@dataclass
class TraceArtifact:
    """One captured entry point plus the expectations the rules enforce.

    ``expect`` keys consumed by the default rules:

    * ``traced``          — the io_callback trace hook is deliberately on;
    * ``pallas_in_loop``  — kernel pack must be active inside the while
      body (unbatched pallas paths); ``pallas_anywhere`` for loop-free
      kernel artifacts; absent/False -> no pallas_call may appear;
    * ``collectives``     — exact in-loop {prim: count} (missing = {});
    * ``dtype``           — the solve dtype; wider floats are leaks;
    * ``max_iter``        — expected top-level while trip bound.
    """

    name: str
    jaxpr: object | None = None  # ClosedJaxpr
    hlo_text: str | None = None
    policy: object | None = None  # kernels.dispatch.KernelPolicy
    opts: object | None = None  # core.mwu.MWUOptions
    plan: object | None = None  # dist.mesh.MeshPlan
    pod_mode: str | None = None
    expect: dict = field(default_factory=dict)

    _hlo_module: object | None = None

    @property
    def hlo(self) -> hlo_ir.HloModule | None:
        if self.hlo_text is None:
            return None
        if self._hlo_module is None:
            self._hlo_module = hlo_ir.parse_hlo(self.hlo_text)
        return self._hlo_module


class Rule:
    """Base class: one invariant, checked per artifact."""

    name: str = "rule"
    description: str = ""

    def check(self, art: TraceArtifact) -> list[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, art, message, *, key="", severity=ERROR, **detail) -> Finding:
        return Finding(
            rule=self.name, severity=severity, artifact=art.name,
            message=message, key=key, detail=detail,
        )


# ------------------------------------------------------------------ rules --
class HostCallbackRule(Rule):
    """No host round-trips inside the hot loop (unless the trace hook is on)."""

    name = "no-callbacks-in-loop"
    description = "no host callbacks / device-to-host transfers inside the MWU while body"

    # pallas custom-call targets are device kernels, not host calls
    _OK_TARGETS = ("tpu_custom_call", "mosaic", "Sharding", "SPMD", "annotate")

    def check(self, art):
        out = []
        traced = bool(art.expect.get("traced"))
        if art.jaxpr is not None:
            counts = count_primitives(art.jaxpr, CALLBACK_PRIMS, in_while_only=True)
            if traced:
                if not counts.get("io_callback"):
                    out.append(self.finding(
                        art, "trace hook expected but no io_callback traced into the loop",
                        key="missing-trace-hook", severity=WARNING,
                    ))
                counts.pop("io_callback", None)
            for prim, n in sorted(counts.items()):
                out.append(self.finding(
                    art,
                    f"{n} `{prim}` host round-trip(s) inside the while loop — "
                    "every MWU iteration now syncs with the host",
                    key=prim, count=n,
                ))
        if art.hlo is not None:
            loop_comps: set[str] = set()
            for w in hlo_ir.while_ops(art.hlo):
                for root in (w["cond"], w["body"]):
                    if root:
                        loop_comps |= hlo_ir.reachable(art.hlo.comps, root)
            for comp, target in hlo_ir.custom_calls(art.hlo, within=loop_comps):
                if any(okay in target for okay in self._OK_TARGETS):
                    continue
                out.append(self.finding(
                    art,
                    f"custom-call `{target}` inside loop computation `{comp}` "
                    "(host callback or un-vetted external call in the hot loop)",
                    key=f"custom-call:{target}", target=target,
                ))
        return out


class KernelPathRule(Rule):
    """The Pallas kernel pack is active exactly when the policy says so."""

    name = "kernel-path"
    description = "pallas_call presence/absence matches the resolved KernelPolicy"

    def check(self, art):
        if art.jaxpr is None:
            return []
        out = []
        in_loop = find_eqns(art.jaxpr, "pallas_call", in_while_only=True)
        anywhere = find_eqns(art.jaxpr, "pallas_call")
        if art.expect.get("pallas_in_loop"):
            if not in_loop:
                out.append(self.finding(
                    art,
                    "KernelPolicy resolves to pallas but no pallas_call was traced "
                    "into the while body — the fused kernel pack silently fell back",
                    key="missing",
                ))
        elif art.expect.get("pallas_anywhere"):
            if not anywhere:
                out.append(self.finding(
                    art, "kernel entry point traced without any pallas_call",
                    key="missing",
                ))
        elif anywhere:
            out.append(self.finding(
                art,
                f"{len(anywhere)} pallas_call(s) traced under an xla/batched policy "
                "(vmapped lanes and xla policies must take the reference path)",
                key="unexpected", count=len(anywhere),
            ))
        interp = getattr(art.policy, "interpret", None)
        if interp is not None:
            for eqn in anywhere:
                if bool(eqn.params.get("interpret")) != bool(interp):
                    out.append(self.finding(
                        art,
                        f"pallas_call interpret={eqn.params.get('interpret')} does not "
                        f"match the resolved policy interpret={interp}",
                        key="interpret-mismatch", severity=WARNING,
                    ))
                    break
        return out


class LoopCollectivesRule(Rule):
    """In-loop collective count/kind == what the pod plan declares."""

    name = "loop-collectives"
    description = "collectives inside the while body match the declared MeshPlan/pod mode"

    def check(self, art):
        if art.jaxpr is None:
            return []
        expected = {k: int(v) for k, v in art.expect.get("collectives", {}).items() if v}
        got = count_primitives(art.jaxpr, COLLECTIVE_PRIMS, in_while_only=True)
        if got == expected:
            return []
        mode = art.pod_mode or "identity"
        return [self.finding(
            art,
            f"in-loop collectives {got or '{}'} != declared {expected or '{}'} for "
            f"pod mode `{mode}` — per-iteration communication changed",
            expected=expected, got=got, pod_mode=mode,
        )]


class DtypeRule(Rule):
    """No f64 ops / weak-type promotions beyond the problem dtype."""

    name = "dtype-discipline"
    description = "no unexpected f64 ops or weak-type promotions in the trace"

    def check(self, art):
        expected = jnp.dtype(art.expect.get("dtype", "float32"))
        if expected.itemsize >= 8:  # f64 solve: nothing wider to leak into
            return []
        out = []
        if art.jaxpr is not None:
            leaks: dict[str, int] = {}
            from .jaxpr_scan import iter_eqns

            for eqn, _ in iter_eqns(art.jaxpr):
                for v in eqn.outvars:
                    dt = getattr(getattr(v, "aval", None), "dtype", None)
                    if dt is not None and jnp.issubdtype(dt, jnp.floating) and jnp.dtype(dt).itemsize > expected.itemsize:
                        leaks[eqn.primitive.name] = leaks.get(eqn.primitive.name, 0) + 1
            if leaks:
                out.append(self.finding(
                    art,
                    f"float ops wider than the {expected.name} problem dtype traced "
                    f"(weak-type promotion leak): {leaks}",
                    key="jaxpr", leaks=leaks,
                ))
        if art.hlo_text is not None:
            n64 = art.hlo_text.count("f64[")
            if n64:
                out.append(self.finding(
                    art,
                    f"{n64} f64 shape(s) survived into compiled HLO of a "
                    f"{expected.name} solve",
                    key="hlo", count=n64,
                ))
        return out


class TripCountRule(Rule):
    """Compiled while trip bound == MWUOptions.max_iter (compile-time check)."""

    name = "trip-count"
    description = "top-level while trip bound in compiled HLO matches MWUOptions.max_iter"

    def check(self, art):
        if art.hlo is None or art.opts is None:
            return []
        max_iter = int(art.expect.get("max_iter", getattr(art.opts, "max_iter", 0)))
        whiles = [w for w in hlo_ir.while_ops(art.hlo) if w["top_level"]]
        if not whiles:
            return [self.finding(
                art,
                "no top-level while loop in compiled HLO — the MWU loop was "
                "unrolled, hoisted or restructured",
                key="missing-loop", severity=WARNING,
            )]
        trips = [hlo_ir.trip_count(art.hlo.comps, w["cond"]) for w in whiles if w["cond"]]
        if max_iter not in trips:
            # None entries are data-dependent loops with no recoverable
            # bound — name them rather than reporting a fabricated 1
            shown = [t if t is not None else "unbounded" for t in trips]
            return [self.finding(
                art,
                f"top-level while trip bound(s) {shown} do not include the "
                f"configured max_iter={max_iter} — the compiled iteration cap "
                "drifted from MWUOptions",
                trips=shown, max_iter=max_iter,
            )]
        return []


class VmemFootprintRule(Rule):
    """Every pallas_call's block footprint fits the dispatch VMEM budget."""

    name = "vmem-footprint"
    description = "BlockSpec footprint (resident + double-buffered tiles) within dispatch headroom"

    def check(self, art):
        if art.jaxpr is None:
            return []
        from ..kernels import dispatch as _kd

        budget = _kd.vmem_budget_bytes()
        out = []
        for eqn in find_eqns(art.jaxpr, "pallas_call"):
            est = self._estimate(eqn)
            if est is None:
                continue
            if est > budget:
                kname = eqn.params.get("name_and_src_info")
                out.append(self.finding(
                    art,
                    f"pallas kernel `{kname}` estimated VMEM footprint "
                    f"{est / 2**20:.2f} MiB exceeds the dispatch budget "
                    f"{budget / 2**20:.2f} MiB "
                    f"(VMEM_BYTES_PER_CORE - VMEM_HEADROOM_BYTES)",
                    key=str(kname).split(" ")[0], bytes=est, budget=budget,
                ))
        return out

    @staticmethod
    def _estimate(eqn) -> int | None:
        gm = eqn.params.get("grid_mapping")
        if gm is None:
            return None
        total = 0
        for bm in getattr(gm, "block_mappings", ()):
            block = [int(b) for b in bm.block_shape if isinstance(b, int) or getattr(b, "__index__", None)]
            sds = getattr(bm, "array_shape_dtype", None)
            if sds is None:
                continue
            nbytes = math.prod(block) * jnp.dtype(sds.dtype).itemsize if block else jnp.dtype(sds.dtype).itemsize
            # full-array blocks are VMEM-resident once; streamed tiles are
            # double-buffered by the Mosaic pipeline
            resident = tuple(block) == tuple(int(d) for d in sds.shape)
            total += nbytes if resident else 2 * nbytes
        return total


DEFAULT_RULES: list[Rule] = [
    HostCallbackRule(),
    KernelPathRule(),
    LoopCollectivesRule(),
    DtypeRule(),
    TripCountRule(),
    VmemFootprintRule(),
]


def run_rules(artifacts, rules=None) -> list[Finding]:
    """Apply ``rules`` (default: all) to every artifact; findings in order."""
    rules = DEFAULT_RULES if rules is None else rules
    findings: list[Finding] = []
    for art in artifacts:
        for rule in rules:
            findings.extend(rule.check(art))
    return findings
