"""CLI entry: ``python -m repro.tracecheck --matrix``.

Device fabrication (``--devices N``) must happen before jax initializes
its backend, so this module parses argv and sets XLA_FLAGS *before*
importing anything that imports jax (capture/rules). CI runs::

    python -m repro.tracecheck --matrix --devices 8 --out TRACECHECK.json

Exit status is 0 iff no error-severity finding is missing from the
baseline allowlist (see :mod:`repro.tracecheck.report`).
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tracecheck",
        description="static jaxpr/HLO lint of the solver's performance invariants",
    )
    ap.add_argument("--matrix", action="store_true", help="run the default case sweep")
    ap.add_argument("--quick", action="store_true", help="trimmed sweep, no HLO compiles")
    ap.add_argument("--list", action="store_true", help="print the case names and exit")
    ap.add_argument("--out", default=None, metavar="PATH", help="write TRACECHECK.json here")
    ap.add_argument("--baseline", default=None, metavar="PATH", help="allowlist file override")
    ap.add_argument(
        "--devices",
        type=int,
        default=0,
        metavar="N",
        help="fabricate N host devices (XLA_FLAGS) so dist cases can run on CPU",
    )
    args = ap.parse_args(argv)

    if args.list:
        from .matrix import default_matrix

        for case in default_matrix(quick=args.quick):
            print(case.name)
        return 0
    if not args.matrix:
        ap.print_help()
        return 2

    if args.devices > 1:
        flag = f"--xla_force_host_platform_device_count={args.devices}"
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + flag).strip()

    from .cli import run_matrix

    report = run_matrix(quick=args.quick, baseline=args.baseline, out=args.out)
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
