"""CLI entry: ``python -m repro.tracecheck --matrix`` / ``--ast``.

Device fabrication (``--devices N``) must happen before jax initializes
its backend, so this module parses argv and sets XLA_FLAGS *before*
importing anything that imports jax (capture/rules). CI runs::

    python -m repro.tracecheck --ast                     # fast, no jax tracing
    python -m repro.tracecheck --matrix --devices 8 \\
        --out TRACECHECK.json --costmodel-out COSTMODEL.json

``--ast`` lints the source tree (stdlib only — :mod:`.astlint` never
imports jax, and the package ``__init__`` is lazy, so this path works
in the dependency-free ruff job too; that job may equivalently execute
``src/repro/tracecheck/astlint.py`` directly).

Exit status is 0 iff no error-severity finding is missing from the
baseline allowlist (see :mod:`repro.tracecheck.report`); ``--ast`` exits
nonzero on any unsuppressed finding (no baseline for source lint).
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tracecheck",
        description="static jaxpr/HLO/source analysis of the solver's performance invariants",
    )
    ap.add_argument("--matrix", action="store_true", help="run the default case sweep")
    ap.add_argument(
        "--ast",
        nargs="*",
        default=None,
        metavar="PATH",
        help="AST source lint (RPR rule codes); default path: the repro package",
    )
    ap.add_argument("--quick", action="store_true", help="trimmed sweep, no HLO compiles")
    ap.add_argument("--list", action="store_true", help="print the case names and exit")
    ap.add_argument("--out", default=None, metavar="PATH", help="write TRACECHECK.json here")
    ap.add_argument(
        "--costmodel-out", default=None, metavar="PATH", help="write COSTMODEL.json here"
    )
    ap.add_argument("--baseline", default=None, metavar="PATH", help="allowlist file override")
    ap.add_argument(
        "--cost-baseline", default=None, metavar="PATH",
        help="cost baseline file override (default: costmodel_baseline.json)",
    )
    ap.add_argument(
        "--update-cost-baseline",
        action="store_true",
        help="rewrite the per-iteration cost baseline from this run's cells",
    )
    ap.add_argument(
        "--prune-baseline",
        action="store_true",
        help="drop baseline fingerprints that no longer fire (prints removals)",
    )
    ap.add_argument(
        "--devices",
        type=int,
        default=0,
        metavar="N",
        help="fabricate N host devices (XLA_FLAGS) so dist cases can run on CPU",
    )
    args = ap.parse_args(argv)

    if args.ast is not None:
        # stdlib-only path: never touches jax
        from . import astlint

        paths = args.ast or [os.path.dirname(os.path.dirname(os.path.abspath(__file__)))]
        findings = astlint.lint_paths(paths)
        print(astlint.format_findings(findings))
        if findings:
            return 1
        if not args.matrix:
            return 0
    if args.list:
        from .matrix import default_matrix

        for case in default_matrix(quick=args.quick):
            print(case.name)
        return 0
    if not args.matrix:
        ap.print_help()
        return 2

    if args.devices > 1:
        flag = f"--xla_force_host_platform_device_count={args.devices}"
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + flag).strip()

    from .cli import run_matrix

    report = run_matrix(
        quick=args.quick,
        baseline=args.baseline,
        out=args.out,
        costmodel_out=args.costmodel_out,
        cost_baseline=args.cost_baseline,
        update_cost_baseline=args.update_cost_baseline,
        prune=args.prune_baseline,
    )
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
