"""Baseline allowlist + TRACECHECK.json reporting.

The baseline (``baseline.json`` next to this module, or any file passed
via ``--baseline``) is ``{"allow": [<fingerprint>, ...]}``: a list of
:attr:`Finding.fingerprint` strings for known, accepted violations.
The gate fails only on *new* error-severity findings, so an intentional
deviation is recorded once (add its fingerprint to the allow list with a
comment in the PR) instead of silencing the rule wholesale. Warnings
never fail the gate; they appear in the report for triage.
"""
from __future__ import annotations

import json
import os

from .rules import ERROR, Finding

__all__ = [
    "DEFAULT_BASELINE",
    "load_baseline",
    "prune_baseline",
    "split_findings",
    "build_report",
    "write_report",
    "summarize",
]

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")


def load_baseline(path: str | None = None) -> set[str]:
    """Allowed fingerprints from a baseline file (missing file = empty)."""
    path = path or DEFAULT_BASELINE
    if not os.path.exists(path):
        return set()
    with open(path) as f:
        data = json.load(f)
    return set(data.get("allow", []))


def prune_baseline(findings: list[Finding], path: str | None = None) -> list[str]:
    """Drop allowlist fingerprints that no longer fire; returns the removed.

    ``--prune-baseline``: a baseline entry whose violation was fixed is
    dead weight that would silently re-admit a future regression with
    the same fingerprint, so the gate offers to garbage-collect them.
    The file is rewritten only when something was actually removed.
    """
    path = path or DEFAULT_BASELINE
    allow = load_baseline(path)
    live = {f.fingerprint for f in findings}
    removed = sorted(allow - live)
    if removed:
        with open(path, "w") as f:
            json.dump({"allow": sorted(allow & live)}, f, indent=2)
            f.write("\n")
    return removed


def split_findings(findings: list[Finding], allow: set[str]):
    """(new, baselined) partition of findings by baseline fingerprint."""
    new = [f for f in findings if f.fingerprint not in allow]
    old = [f for f in findings if f.fingerprint in allow]
    return new, old


def build_report(cases, artifacts, findings, allow, *, skipped=()) -> dict:
    """The TRACECHECK.json payload. ``ok`` gates the process exit code."""
    new, baselined = split_findings(findings, allow)
    new_errors = [f for f in new if f.severity == ERROR]
    return {
        "matrix": [c.name for c in cases],
        "artifacts": [a.name for a in artifacts],
        "skipped": list(skipped),
        "findings": [
            {**f.as_dict(), "baselined": f.fingerprint in allow} for f in findings
        ],
        "n_findings": len(findings),
        "n_new_errors": len(new_errors),
        "n_baselined": len(baselined),
        "ok": not new_errors,
    }


def write_report(report: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")


def summarize(report: dict) -> str:
    """Human one-screen summary for CLI stdout / bench logs."""
    lines = [
        f"tracecheck: {len(report['artifacts'])} artifact(s) from "
        f"{len(report['matrix'])} case(s)"
        + (f", {len(report['skipped'])} skipped (too few devices)" if report["skipped"] else "")
    ]
    for f in report["findings"]:
        tag = "baselined" if f["baselined"] else f["severity"]
        lines.append(f"  [{tag}] {f['fingerprint']}: {f['message']}")
    if not report["findings"]:
        lines.append("  no findings")
    lines.append(
        "PASS" if report["ok"] else f"FAIL: {report['n_new_errors']} new error finding(s)"
    )
    return "\n".join(lines)
