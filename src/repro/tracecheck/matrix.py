"""The family × backend × mesh-plan sweep both gates share.

:func:`default_matrix` is the single definition of "every hot entry
point": the CLI lint gate (``python -m repro.tracecheck --matrix``), the
CI job and the ``benchmarks/run.py tracecheck`` section all iterate the
same :class:`Case` list, so the benched configurations and the linted
configurations cannot drift apart.

A :class:`Case` is pure host data (no jax imports here): entry kind,
problem family, kernel backend, and for dist cases the (pod, data) plan.
Capture happens in :mod:`.capture`.
"""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["Case", "default_matrix"]


@dataclass(frozen=True)
class Case:
    """One cell of the tracecheck sweep."""

    entry: str  # solve | solve_batch | solve_traced | dist | lpserve | kernel
    family: str = ""  # problem family ("" for kernel cases)
    backend: str = "xla"  # kernel_backend passed to MWUOptions
    pod: int = 1  # dist only
    data: int = 1  # dist only
    lanes: int = 4  # lpserve only
    hlo: bool = False  # also compile + lint the HLO (slower)
    op: str = ""  # kernel cases: gather | softmax | probe | axpy

    @property
    def name(self) -> str:
        if self.entry == "kernel":
            return f"kernel:{self.op}"
        bits = [self.entry, self.family, self.backend]
        if self.entry == "dist":
            bits.append(f"pod{self.pod}x{self.data}")
        return ":".join(bits)


def default_matrix(quick: bool = False) -> list[Case]:
    """The default sweep (``quick`` trims families and skips HLO compiles).

    Composition:

    * ``solve`` per family under both backends, with compiled-HLO lint
      on both (trip count, f64 survival, loop custom-calls on xla; the
      pallas cells feed the per-iteration cost model so family × backend
      cost cells exist for every family);
    * one ``solve_traced`` cell (the io_callback hook must be traced,
      and only when asked for);
    * ``solve_batch`` per family (vmapped lanes: kernel pack must be
      absent by the custom_vmap design);
    * ``dist`` plans: identity (1,1) **per family** — the jaxpr parity
      prover diffs each against the plain ``solve_batch`` trace — plus
      pod-sharded (2,1) and data-sharded (1,2) under both backends
      (skipped at runtime when the process has fewer devices; the xla
      multi-device cells compile so the cost model sees mesh-plan cells);
    * one ``lpserve`` engine audit per backend (every (family, bucket)
      dispatch key it assembles);
    * each Pallas kernel at its dispatch-gate limit shape (VMEM rule).
    """
    families = ["match", "vcover"] if quick else ["match", "vcover", "dense-sub", "gen-match"]
    hlo = not quick
    cases: list[Case] = []

    for fam in families:
        for backend in ("xla", "pallas"):
            cases.append(Case("solve", fam, backend, hlo=hlo))
        cases.append(Case("solve_batch", fam, "xla", hlo=hlo and fam == families[0]))
        cases.append(Case("dist", fam, "xla", pod=1, data=1))
    cases.append(Case("solve_batch", families[0], "pallas"))
    cases.append(Case("solve_traced", families[0], "xla"))

    for backend in ("xla", "pallas"):
        cases.append(Case("dist", families[0], backend, pod=2, data=1, hlo=hlo and backend == "xla"))
        cases.append(Case("dist", families[0], backend, pod=1, data=2, hlo=hlo and backend == "xla"))
    if not quick:
        cases.append(Case("dist", "gen-match", "xla", pod=2, data=1))

    cases.append(Case("lpserve", families[0], "xla", hlo=False))
    if not quick:
        cases.append(Case("lpserve", "vcover", "pallas"))

    for op in ("gather", "softmax", "probe", "axpy"):
        cases.append(Case("kernel", op=op))
    return cases
