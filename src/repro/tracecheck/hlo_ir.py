"""Shared textual-HLO IR: one parser for the roofline analyzer and the linter.

Historically :mod:`repro.utils.hlo` owned a private parser for its
roofline terms; ``repro.tracecheck`` needs the same structure (ops,
computations, while condition/body wiring, trip counts) to lint compiled
programs, so the parser lives here and both consumers import it. The IR
is deliberately *textual*: it parses ``compiled.as_text()`` (post-fusion
scheduled HLO), which is the program XLA actually runs — jaxpr-level
checks see the pre-compilation view instead (:mod:`.jaxpr_scan`).

Structure:

* :class:`Op`           — one instruction (name, result type, kind, raw tail);
* :class:`Computation`  — one ``%comp { ... }`` block with a name index;
* :class:`HloModule`    — all computations + the ``ENTRY`` name;
* :func:`parse_hlo`     — text -> :class:`HloModule`;
* :func:`trip_count`    — loop bound of a ``while`` condition: the max
  integer literal on an operand path *into a compare op* (unrelated
  constants in the condition cannot inflate it — see the regression
  test in tests/test_hlo_analyzer.py);
* :func:`reachable` / :func:`while_ops` / :func:`custom_calls` — graph
  helpers the tracecheck rules and the roofline walker share.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = [
    "DTYPE_BYTES",
    "Op",
    "Computation",
    "HloModule",
    "parse_hlo",
    "shape_bytes",
    "shape_dims",
    "group_size",
    "reachable",
    "trip_count",
    "while_ops",
    "custom_calls",
]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]+?\)?)\s+([a-z][a-z0-9\-]*)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_CALLS_RE = re.compile(r"(?:calls|to_apply|condition|body)=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def shape_bytes(type_str: str) -> int:
    """Total byte size of every shape literal in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> list[int]:
    """Dims of the first shape literal in an HLO type string."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class Op:
    """One HLO instruction, kept close to its textual form."""

    name: str
    type_str: str
    kind: str
    rest: str  # operands + attrs (raw tail of the line)

    @property
    def operands(self) -> list[str]:
        # operand names appear before the closing paren of the call
        depth = 0
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    head = self.rest[:i]
                    break
                depth -= 1
        else:
            head = self.rest
        return re.findall(r"%([\w.\-]+)", head)

    @property
    def attrs(self) -> str:
        return self.rest

    def called_comps(self) -> list[str]:
        """Computation names this op references (calls/body/condition/branches)."""
        out = _CALLS_RE.findall(self.rest)
        bm = _BRANCHES_RE.search(self.rest)
        if bm:
            out += re.findall(r"%([\w.\-]+)", bm.group(1))
        return out

    def const_int(self) -> int | None:
        """The integer literal of a scalar ``constant(N)`` op, else None."""
        if self.kind != "constant":
            return None
        m = re.match(r"\s*(\d+)\)", self.rest)
        return int(m.group(1)) if m else None


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    by_name: dict = field(default_factory=dict)


@dataclass
class HloModule:
    """Parsed module: computations by name plus the ENTRY computation."""

    comps: dict[str, Computation] = field(default_factory=dict)
    entry: str | None = None

    def entry_comp(self) -> Computation | None:
        return self.comps.get(self.entry) if self.entry else None


def parse_hlo(text: str) -> HloModule:
    """Parse ``compiled.as_text()`` into an :class:`HloModule`."""
    mod = HloModule()
    cur: Computation | None = None
    for line in text.splitlines():
        if "/*" in line:  # strip /*index=N*/ tuple comments ('=' breaks _OP_RE)
            line = _COMMENT_RE.sub("", line)
        if cur is None:
            m = _COMP_RE.match(line)
            if m and ("->" in line):
                cur = Computation(name=m.group(1))
                if line.lstrip().startswith("ENTRY"):
                    mod.entry = cur.name
            continue
        if line.startswith("}"):
            mod.comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(name=m.group(1), type_str=m.group(2), kind=m.group(3), rest=m.group(4))
            cur.ops.append(op)
            cur.by_name[op.name] = op
    if mod.entry is None and mod.comps:
        mod.entry = list(mod.comps)[-1]
    return mod


def group_size(attrs: str, num_partitions: int) -> int:
    """Participant count of a collective from its replica_groups attr."""
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    return max(num_partitions, 1)


def reachable(comps: dict[str, Computation], root: str) -> set[str]:
    """Names of every computation reachable from ``root`` via call edges."""
    seen: set[str] = set()
    stack = [root]
    while stack:
        cn = stack.pop()
        if cn in seen or cn not in comps:
            continue
        seen.add(cn)
        for op in comps[cn].ops:
            stack.extend(op.called_comps())
    return seen


def trip_count(comps: dict[str, Computation], cond_name: str) -> int | None:
    """Loop bound recovered from a ``while`` condition computation.

    Only integer constants on an operand path *into a compare op* count
    (the loop-bound test is always a compare against the bound constant,
    possibly inside a fused condition). An unrelated large integer
    literal elsewhere in the condition — a gather dimension, an address
    constant — therefore cannot inflate the estimate, which the previous
    max-literal-anywhere heuristic allowed.

    Returns ``None`` when no compare-fed constant exists (a condition
    comparing two loop-carried values is a genuinely data-dependent
    loop) — callers that need a multiplier must choose their own
    fallback (``trip_count(...) or 1``) instead of this function
    fabricating a bogus bound of 1.
    """
    best: int | None = None
    for cn in reachable(comps, cond_name):
        comp = comps[cn]
        for op in comp.ops:
            if op.kind != "compare":
                continue
            stack = list(op.operands)
            seen: set[str] = set()
            while stack:
                nm = stack.pop()
                if nm in seen:
                    continue
                seen.add(nm)
                src = comp.by_name.get(nm)
                if src is None:
                    continue
                v = src.const_int()
                if v is not None:
                    best = v if best is None else max(best, v)
                    continue
                stack.extend(src.operands)
    return best


def while_ops(mod: HloModule) -> list[dict]:
    """Every ``while`` op in the module, with its wiring and nesting level.

    Returns dicts of ``op``, ``comp`` (owning computation name),
    ``cond`` / ``body`` (computation names or None), and ``top_level``
    (True when the while sits in a computation reachable from ENTRY
    *without* passing through another while's body — i.e. the outer
    loop(s) of the program, for solvers the MWU iteration loop).
    """
    out = []
    body_comps: set[str] = set()
    for comp in mod.comps.values():
        for op in comp.ops:
            if op.kind != "while":
                continue
            body = re.search(r"body=%([\w.\-]+)", op.rest)
            if body:
                body_comps |= reachable(mod.comps, body.group(1))
    for comp in mod.comps.values():
        for op in comp.ops:
            if op.kind != "while":
                continue
            cond = re.search(r"condition=%([\w.\-]+)", op.rest)
            body = re.search(r"body=%([\w.\-]+)", op.rest)
            out.append(
                {
                    "op": op,
                    "comp": comp.name,
                    "cond": cond.group(1) if cond else None,
                    "body": body.group(1) if body else None,
                    "top_level": comp.name not in body_comps,
                }
            )
    return out


def custom_calls(mod: HloModule, within: set[str] | None = None) -> list[tuple[str, str]]:
    """(computation, custom_call_target) pairs, optionally restricted."""
    out = []
    for comp in mod.comps.values():
        if within is not None and comp.name not in within:
            continue
        for op in comp.ops:
            if op.kind != "custom-call":
                continue
            m = re.search(r'custom_call_target="([^"]*)"', op.rest)
            out.append((comp.name, m.group(1) if m else ""))
    return out
