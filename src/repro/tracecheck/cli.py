"""Programmatic runner behind ``python -m repro.tracecheck``.

:func:`run_matrix` captures every case, applies the three analysis
passes and builds the report dict; :mod:`.__main__` wraps it in argument
parsing and the exit code. ``benchmarks/run.py``'s ``tracecheck``
section calls :func:`run_matrix` directly so the bench driver and the
lint gate share one matrix definition
(:func:`repro.tracecheck.matrix.default_matrix`).

The passes, in order:

1. **rules** — the per-artifact jaxpr/HLO invariants (:mod:`.rules`);
2. **parity** — differential jaxpr proofs (:mod:`.diff`): pallas-vs-xla
   ``solve`` traces per family, and identity-plan ``DistSolver`` vs
   plain ``Solver`` ``solve_batch`` traces per family, paired from the
   already-captured artifacts;
3. **costmodel** — per-iteration FLOP/byte/collective counters of every
   compiled cell against the committed baseline (:mod:`.costmodel`).

A capture hook that raises does not abort the sweep: the failed cell
becomes an error finding naming the family / backend / mesh plan (rule
``capture-error``) and the remaining artifacts are still linted.
"""
from __future__ import annotations

from .matrix import Case, default_matrix
from .report import build_report, load_baseline, prune_baseline, summarize, write_report
from .rules import run_rules

__all__ = ["run_matrix", "CAPTURE_RULE"]

CAPTURE_RULE = "capture-error"


def _capture_finding(case, exc):
    from .rules import ERROR, Finding

    bits = [f"family `{case.family or '-'}`", f"backend `{case.backend}`"]
    if case.entry == "dist":
        bits.append(f"mesh plan pod{case.pod}x{case.data}")
    return Finding(
        rule=CAPTURE_RULE, severity=ERROR, artifact=case.name, key=type(exc).__name__,
        message=(
            f"capture of `{case.entry}` ({', '.join(bits)}) raised "
            f"{type(exc).__name__}: {exc} — the entry point no longer lowers; "
            "remaining artifacts were still linted"
        ),
        detail={"entry": case.entry, "family": case.family,
                "backend": case.backend, "pod": case.pod, "data": case.data,
                "error": f"{type(exc).__name__}: {exc}"},
    )


def _parity_findings(artifacts):
    """Differential jaxpr proofs over the captured artifact pairs."""
    from .diff import check_backend_parity, check_dist_identity

    by_name = {a.name: a for a in artifacts}
    findings = []
    fams = []
    for a in artifacts:
        parts = a.name.split(":")
        if parts[0] == "solve" and len(parts) == 3 and parts[1] not in fams:
            fams.append(parts[1])
    for fam in fams:
        ax = by_name.get(f"solve:{fam}:xla")
        ap = by_name.get(f"solve:{fam}:pallas")
        if ax is not None and ap is not None and ax.jaxpr is not None and ap.jaxpr is not None:
            findings.extend(
                check_backend_parity(ax.jaxpr, ap.jaxpr, f"parity:{fam}:backend")
            )
        ab = by_name.get(f"solve_batch:{fam}:xla")
        ad = by_name.get(f"dist:{fam}:xla:pod1x1")
        if ab is not None and ad is not None and ab.jaxpr is not None and ad.jaxpr is not None:
            findings.extend(
                check_dist_identity(ab.jaxpr, ad.jaxpr, f"parity:{fam}:dist")
            )
    return findings


def run_matrix(
    cases: list[Case] | None = None,
    *,
    quick: bool = False,
    baseline: str | None = None,
    out: str | None = None,
    costmodel_out: str | None = None,
    cost_baseline: str | None = None,
    update_cost_baseline: bool = False,
    prune: bool = False,
    verbose: bool = True,
) -> dict:
    """Capture + lint the sweep; returns the report dict (see ``ok`` key).

    Cases whose mesh plan needs more devices than the process has are
    reported under ``skipped`` rather than failing — CI fabricates
    devices via ``--devices`` / XLA_FLAGS, single-device runs still lint
    everything else. ``update_cost_baseline`` rewrites the committed
    per-iteration cost baseline from this run's cells instead of gating
    against it; ``prune`` drops baseline-allowlist fingerprints that no
    longer fire.
    """
    from . import costmodel as _cm
    from .capture import capture_case  # imports jax: keep lazy for --devices

    cases = default_matrix(quick=quick) if cases is None else cases
    artifacts = []
    skipped = []
    findings = []
    for case in cases:
        try:
            got = capture_case(case)
        except Exception as exc:  # noqa: BLE001 - any lowering failure is the finding
            findings.append(_capture_finding(case, exc))
            continue
        if got is None:
            skipped.append(case.name)
            continue
        artifacts.extend(got if isinstance(got, list) else [got])

    findings.extend(run_rules(artifacts))
    findings.extend(_parity_findings(artifacts))

    cells = _cm.cost_cells(artifacts)
    if update_cost_baseline:
        path = _cm.write_cost_baseline(cells, cost_baseline)
        if verbose:
            print(f"costmodel: baseline rewritten with {len(cells)} cell(s) at {path}")
    cost_base = _cm.load_cost_baseline(cost_baseline)
    cost_findings = _cm.check_costs(cells, cost_base)
    findings.extend(cost_findings)
    if costmodel_out:
        write_report(_cm.build_costmodel_report(cells, cost_base, cost_findings), costmodel_out)

    allow = load_baseline(baseline)
    report = build_report(cases, artifacts, findings, allow, skipped=skipped)
    report["cost_cells"] = sorted(cells)
    if prune:
        removed = prune_baseline(findings, baseline)
        report["pruned"] = removed
        if verbose:
            for fp in removed:
                print(f"pruned stale baseline fingerprint: {fp}")
            if not removed:
                print("baseline: nothing to prune")
    if out:
        write_report(report, out)
    if verbose:
        print(summarize(report))
    return report
