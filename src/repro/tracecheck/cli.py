"""Programmatic runner behind ``python -m repro.tracecheck``.

:func:`run_matrix` captures every case, applies the rule set and builds
the report dict; :mod:`.__main__` wraps it in argument parsing and the
exit code. ``benchmarks/run.py``'s ``tracecheck`` section calls
:func:`run_matrix` directly so the bench driver and the lint gate share
one matrix definition (:func:`repro.tracecheck.matrix.default_matrix`).
"""
from __future__ import annotations

from .matrix import Case, default_matrix
from .report import build_report, load_baseline, summarize, write_report
from .rules import run_rules

__all__ = ["run_matrix"]


def run_matrix(
    cases: list[Case] | None = None,
    *,
    quick: bool = False,
    baseline: str | None = None,
    out: str | None = None,
    verbose: bool = True,
) -> dict:
    """Capture + lint the sweep; returns the report dict (see ``ok`` key).

    Cases whose mesh plan needs more devices than the process has are
    reported under ``skipped`` rather than failing — CI fabricates
    devices via ``--devices`` / XLA_FLAGS, single-device runs still lint
    everything else.
    """
    from .capture import capture_case  # imports jax: keep lazy for --devices

    cases = default_matrix(quick=quick) if cases is None else cases
    artifacts = []
    skipped = []
    for case in cases:
        got = capture_case(case)
        if got is None:
            skipped.append(case.name)
            continue
        artifacts.extend(got if isinstance(got, list) else [got])

    findings = run_rules(artifacts)
    allow = load_baseline(baseline)
    report = build_report(cases, artifacts, findings, allow, skipped=skipped)
    if out:
        write_report(report, out)
    if verbose:
        print(summarize(report))
    return report
