"""AST trace-safety lint over the repo source (the RPR rule codes).

The jaxpr/HLO passes in :mod:`.rules` lint *captured programs*; this
module lints the *source tree itself* for the coding patterns that
produce those regressions in the first place — so the fast pre-jax CI
step (and the ruff lint job, which has no jax installed) can reject a
bad diff in seconds. Pure stdlib: importing this module must never pull
in jax.

Rule codes (each is one way a trace silently goes wrong):

``RPR001``  ``jax.default_backend()`` / ``os.environ`` reads inside a
            traced function — the value is frozen into the jit cache at
            first trace and goes stale when the device set or env
            changes. Resolve host-side and pass the result through as a
            static argument (``kernels.dispatch.resolve``).
``RPR002``  Python ``if``/``while`` branching on a traced function's
            argument (or a value derived from one) — a tracer has no
            truth value at runtime; use ``lax.cond`` / ``jnp.where`` or
            declare the argument static.
``RPR003``  bare ``float64`` dtype literals in kernel / core / model
            modules — the solver is dtype-generic via promotion rules;
            a hard-coded f64 literal widens every downstream op (use
            ``jnp.result_type`` / ``jnp.promote_types``).
``RPR004``  ``io_callback`` outside the sanctioned ``Solver`` trace hook
            (:mod:`repro.core.mwu`) — every other in-loop host callback
            is a per-iteration device stall the no-callbacks rule will
            reject at trace time anyway.
``RPR005``  a literal list/dict/set passed for a parameter declared in
            ``static_argnames`` of a module-local jitted function —
            static args must be hashable; the call raises (or worse,
            retraces per call when wrapped).
``RPR006``  ``warnings.warn(..., DeprecationWarning)`` outside
            :mod:`repro.utils.deprecation` — deprecations must funnel
            through ``warn_once`` so long-running processes warn once
            per shim, not once per call.

Suppression is per line: append ``# repro: noqa[RPR001]`` (one or more
comma-separated codes) to the flagged line. There is deliberately *no*
fingerprint baseline for this pass — a source-level violation is either
fixed or annotated where it stands.

CLI: ``python -m repro.tracecheck --ast [paths...]`` (default:
``src/repro``); exits nonzero on any unsuppressed finding.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field

__all__ = [
    "RPR_RULES",
    "AstFinding",
    "lint_source",
    "lint_file",
    "lint_paths",
    "format_findings",
]

RPR_RULES = {
    "RPR001": "backend/env read inside a traced function",
    "RPR002": "Python branch on a traced value",
    "RPR003": "bare float64 literal in kernel/core/model module",
    "RPR004": "io_callback outside the sanctioned trace hook",
    "RPR005": "unhashable literal passed as a jit static argument",
    "RPR006": "DeprecationWarning not routed through utils.deprecation.warn_once",
}

# modules allowed to contain what a rule forbids elsewhere
_RPR003_SCOPES = ("kernels", "core", "models")  # package dirs under repro
_RPR004_SANCTIONED = ("core/mwu.py", "core\\mwu.py")
_RPR006_SANCTIONED = ("utils/deprecation.py", "utils\\deprecation.py")

_NOQA_RE = re.compile(r"#\s*repro:\s*noqa\[([A-Z0-9, ]+)\]")

# names whose call argument becomes a traced function body
_TRACE_CONSUMERS = {
    "while_loop", "fori_loop", "scan", "cond", "switch", "map",
    "jit", "vmap", "pmap", "grad", "value_and_grad", "checkpoint",
    "remat", "custom_vmap", "shard_map", "associative_scan",
}
# decorator heads that make the decorated function traced
_TRACE_DECORATORS = {"jit", "vmap", "pmap", "grad", "value_and_grad", "checkpoint", "remat", "custom_vmap"}


@dataclass
class AstFinding:
    """One source-level rule violation (pre-jax sibling of rules.Finding)."""

    code: str
    path: str
    line: int
    col: int
    message: str
    symbol: str = ""

    @property
    def fingerprint(self) -> str:
        sym = self.symbol or f"L{self.line}"
        return f"{self.code}::{self.path}::{sym}"

    def as_dict(self) -> dict:
        return {
            "code": self.code,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "fingerprint": self.fingerprint,
        }


def _attr_chain(node: ast.AST) -> str:
    """Dotted name of an attribute/name chain ('' when not a plain chain)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _tail(chain: str) -> str:
    return chain.rsplit(".", 1)[-1] if chain else ""


def _is_env_read(node: ast.AST) -> bool:
    """os.environ[...] / os.environ.get(...) / os.getenv(...) / jax.default_backend()."""
    if isinstance(node, ast.Call):
        chain = _attr_chain(node.func)
        if chain.endswith("os.getenv") or chain == "getenv":
            return True
        if chain.endswith("environ.get"):
            return True
        if chain.endswith("default_backend"):
            return True
    if isinstance(node, ast.Subscript):
        chain = _attr_chain(node.value)
        if chain.endswith("os.environ") or chain == "environ":
            return True
    return False


def _decorator_is_traced(dec: ast.AST) -> bool:
    """@jax.jit, @jit, @partial(jax.jit, ...), @jax.custom_batching.custom_vmap ..."""
    if isinstance(dec, ast.Call):
        head = _attr_chain(dec.func)
        if _tail(head) == "partial" and dec.args:
            return _decorator_is_traced(dec.args[0])
        return _tail(head) in _TRACE_DECORATORS
    return _tail(_attr_chain(dec)) in _TRACE_DECORATORS


class _FunctionInfo:
    """One function scope: its node, whether it is proven traced, children."""

    def __init__(self, node, parent=None):
        self.node = node
        self.parent = parent
        self.traced = any(_decorator_is_traced(d) for d in getattr(node, "decorator_list", ()))
        # params declared static at the jit decorator: branching on them
        # is host-side control flow, not a tracer branch (RPR002 exempt)
        self.static_params: set[str] = set()
        for d in getattr(node, "decorator_list", ()):
            self.static_params |= _Linter._jit_static_names(d)
        self.children: dict[str, _FunctionInfo] = {}

    def mark_traced(self):
        if not self.traced:
            self.traced = True
            # everything defined inside a traced function traces with it
            for ch in self.children.values():
                ch.mark_traced()

    def effective_traced(self) -> bool:
        info = self
        while info is not None:
            if info.traced:
                return True
            info = info.parent
        return False


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel.replace(os.sep, "/")
        self.findings: list[AstFinding] = []
        self.scope: _FunctionInfo | None = None
        self.scopes: list[_FunctionInfo] = []
        # RPR005: module-local jitted callables -> their static argnames
        self.static_argnames: dict[str, set[str]] = {}
        self.noqa = self._noqa_lines(source)
        parts = self.rel.split("/")
        self.in_rpr003_scope = any(p in _RPR003_SCOPES for p in parts)
        self.rpr004_ok = any(self.rel.endswith(s.replace("\\", "/")) for s in _RPR004_SANCTIONED)
        self.rpr006_ok = any(self.rel.endswith(s.replace("\\", "/")) for s in _RPR006_SANCTIONED)

    @staticmethod
    def _noqa_lines(source: str) -> dict[int, set[str]]:
        out: dict[int, set[str]] = {}
        for i, line in enumerate(source.splitlines(), start=1):
            m = _NOQA_RE.search(line)
            if m:
                out[i] = {c.strip() for c in m.group(1).split(",") if c.strip()}
        return out

    def emit(self, code: str, node: ast.AST, message: str, symbol: str = ""):
        line = getattr(node, "lineno", 0)
        allowed = self.noqa.get(line, ())
        if code in allowed:
            return
        self.findings.append(AstFinding(
            code=code, path=self.rel, line=line,
            col=getattr(node, "col_offset", 0), message=message, symbol=symbol,
        ))

    # -- scope bookkeeping -------------------------------------------------
    def _qualname(self) -> str:
        names = []
        info = self.scope
        while info is not None:
            names.append(info.node.name if hasattr(info.node, "name") else "<lambda>")
            info = info.parent
        return ".".join(reversed(names))

    def _enter_function(self, node):
        info = _FunctionInfo(node, parent=self.scope)
        if self.scope is not None and hasattr(node, "name"):
            self.scope.children[node.name] = info
        self.scopes.append(info)
        prev, self.scope = self.scope, info
        self._collect_static_argnames(node)
        self.generic_visit(node)
        self.scope = prev

    def visit_FunctionDef(self, node):
        self._enter_function(node)

    def visit_AsyncFunctionDef(self, node):
        self._enter_function(node)

    def visit_Lambda(self, node):
        info = _FunctionInfo(node, parent=self.scope)
        self.scopes.append(info)
        prev, self.scope = self.scope, info
        self.generic_visit(node)
        self.scope = prev

    # -- traced-ness propagation ------------------------------------------
    def visit_Call(self, node):
        head = _tail(_attr_chain(node.func))
        if head in _TRACE_CONSUMERS and self.scope is not None:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name) and arg.id in self.scope.children:
                    self.scope.children[arg.id].mark_traced()
                elif isinstance(arg, ast.Lambda):
                    pass  # visited as its own scope; lambdas passed to
                    # trace consumers are rarely backend-reading — skip
        self._check_rpr004(node)
        self._check_rpr006(node)
        self._check_rpr005_call(node)
        self.generic_visit(node)

    # -- the per-function checks ------------------------------------------
    def finish(self):
        """Emit RPR001/RPR002 once the whole module has been walked.

        Traced-ness of a locally-defined function is discovered at its
        *use site* in the enclosing scope (passed to while_loop/jit/...),
        which may come before or after the def statement — so these two
        rules run as a second pass over the recorded scopes instead of
        during the visit.
        """
        for info in self.scopes:
            if not info.effective_traced():
                continue
            node = info.node
            name = getattr(node, "name", "<lambda>")
            own = list(self._walk_own(node))
            tainted = {a.arg for a in self._params(node)} - info.static_params
            for stmt in own:
                if isinstance(stmt, ast.Assign):
                    if self._expr_tainted(stmt.value, tainted):
                        for tgt in stmt.targets:
                            for n in ast.walk(tgt):
                                if isinstance(n, ast.Name):
                                    tainted.add(n.id)
                elif isinstance(stmt, ast.AugAssign):
                    if self._expr_tainted(stmt.value, tainted) and isinstance(stmt.target, ast.Name):
                        tainted.add(stmt.target.id)
            for sub in own:
                if _is_env_read(sub):
                    self.emit(
                        "RPR001", sub,
                        f"backend/env read inside traced function `{name}` — "
                        "resolve host-side and pass through as a static arg "
                        "(kernels.dispatch.resolve)",
                        symbol=name,
                    )
                if isinstance(sub, (ast.If, ast.While)) and self._branches_on_tracer(sub.test, tainted):
                    self.emit(
                        "RPR002", sub,
                        f"Python `{'if' if isinstance(sub, ast.If) else 'while'}` on a "
                        f"traced value inside `{name}` — use lax.cond/jnp.where or "
                        "declare the argument static",
                        symbol=name,
                    )

    @classmethod
    def _walk_own(cls, root) -> "list[ast.AST]":
        """Walk a function's own body, stopping at nested function scopes
        (each nested scope is linted as its own entry in ``self.scopes``,
        inheriting traced-ness via ``effective_traced``)."""
        out: list[ast.AST] = []
        stack = list(ast.iter_child_nodes(root))
        while stack:
            n = stack.pop()
            out.append(n)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                continue
            stack.extend(ast.iter_child_nodes(n))
        return out

    @staticmethod
    def _params(node) -> list:
        args = node.args
        return list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs)

    # attribute reads on a tracer that are static python values — values
    # derived from them are host-side, not traced
    _STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "aval", "itemsize", "name"})

    @classmethod
    def _expr_tainted(cls, expr: ast.AST, tainted: set[str]) -> bool:
        stack = [expr]
        while stack:
            n = stack.pop()
            if isinstance(n, ast.Attribute) and n.attr in cls._STATIC_ATTRS:
                continue  # x.shape etc. is static even when x is a tracer
            if isinstance(n, ast.Name) and n.id in tainted:
                return True
            stack.extend(ast.iter_child_nodes(n))
        return False

    def _branches_on_tracer(self, test: ast.AST, tainted: set[str]) -> bool:
        # `x is None` / isinstance / hasattr tests are host-side idioms
        # even on traced args (None-vs-array plumbing) — not violations.
        if isinstance(test, ast.Compare) and any(
            isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops
        ):
            return False
        if isinstance(test, ast.Call) and _tail(_attr_chain(test.func)) in (
            "isinstance", "hasattr", "callable", "len",
        ):
            return False
        if isinstance(test, ast.BoolOp):
            return any(self._branches_on_tracer(v, tainted) for v in test.values)
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            return self._branches_on_tracer(test.operand, tainted)
        return self._expr_tainted(test, tainted)

    # -- RPR003: bare float64 literals ------------------------------------
    def visit_Attribute(self, node):
        if self.in_rpr003_scope and node.attr == "float64":
            chain = _attr_chain(node)
            if chain in ("jnp.float64", "np.float64", "numpy.float64", "jax.numpy.float64"):
                self.emit(
                    "RPR003", node,
                    f"bare `{chain}` literal — derive the wide dtype from the "
                    "inputs (jnp.result_type/jnp.promote_types) so the solver "
                    "stays dtype-generic",
                )
        self.generic_visit(node)

    def visit_Constant(self, node):
        if self.in_rpr003_scope and node.value == "float64" and isinstance(node.value, str):
            self.emit(
                "RPR003", node,
                "bare 'float64' dtype string — derive the dtype from the inputs",
            )
        self.generic_visit(node)

    # -- RPR004 / RPR006 ---------------------------------------------------
    def _check_rpr004(self, node: ast.Call):
        if self.rpr004_ok:
            return
        if _tail(_attr_chain(node.func)) == "io_callback":
            self.emit(
                "RPR004", node,
                "io_callback outside the sanctioned Solver trace hook "
                "(repro.core.mwu) — in-loop host callbacks stall the device "
                "every MWU iteration",
                symbol=self._qualname(),
            )

    def _check_rpr006(self, node: ast.Call):
        if self.rpr006_ok:
            return
        if _tail(_attr_chain(node.func)) != "warn":
            return
        chain = _attr_chain(node.func)
        if chain not in ("warnings.warn", "warn"):
            return
        refs = list(node.args) + [kw.value for kw in node.keywords]
        for arg in refs:
            for n in ast.walk(arg):
                if isinstance(n, (ast.Name, ast.Attribute)) and _tail(_attr_chain(n)) == "DeprecationWarning":
                    self.emit(
                        "RPR006", node,
                        "DeprecationWarning raised directly — route through "
                        "utils.deprecation.warn_once so it fires once per process",
                        symbol=self._qualname(),
                    )
                    return

    # -- RPR005: static-arg hashability ------------------------------------
    def _collect_static_argnames(self, node):
        """Record `@partial(jax.jit, static_argnames=...)`-style functions."""
        for dec in getattr(node, "decorator_list", ()):
            names = self._jit_static_names(dec)
            if names:
                self.static_argnames[node.name] = names

    @staticmethod
    def _jit_static_names(call: ast.AST) -> set[str]:
        if not isinstance(call, ast.Call):
            return set()
        head = _tail(_attr_chain(call.func))
        inner_is_jit = head in ("jit", "pjit")
        if head == "partial" and call.args:
            inner_is_jit = _tail(_attr_chain(call.args[0])) in ("jit", "pjit")
        if not inner_is_jit:
            return set()
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                names: set[str] = set()
                v = kw.value
                if isinstance(v, ast.Constant) and isinstance(v.value, str):
                    names.add(v.value)
                elif isinstance(v, (ast.Tuple, ast.List, ast.Set)):
                    for el in v.elts:
                        if isinstance(el, ast.Constant) and isinstance(el.value, str):
                            names.add(el.value)
                return names
        return set()

    def visit_Assign(self, node):
        # f = jax.jit(g, static_argnames=(...)) at any scope
        names = self._jit_static_names(node.value)
        if names:
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self.static_argnames[tgt.id] = names
        self.generic_visit(node)

    _MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp, ast.SetComp)

    def _check_rpr005_call(self, node: ast.Call):
        fname = None
        if isinstance(node.func, ast.Name):
            fname = node.func.id
        elif isinstance(node.func, ast.Attribute):
            fname = node.func.attr
        statics = self.static_argnames.get(fname or "", None)
        if not statics:
            return
        for kw in node.keywords:
            if kw.arg in statics and isinstance(kw.value, self._MUTABLE_LITERALS):
                self.emit(
                    "RPR005", kw.value,
                    f"unhashable {type(kw.value).__name__.lower()} literal passed for "
                    f"static argument `{kw.arg}` of jitted `{fname}` — static args "
                    "must be hashable (tuple / frozen dataclass)",
                    symbol=fname,
                )


def lint_source(source: str, rel_path: str, path: str = "") -> list[AstFinding]:
    """Lint one module's source text; ``rel_path`` keys the scope rules."""
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [AstFinding(
            code="RPR000", path=rel_path.replace(os.sep, "/"),
            line=exc.lineno or 0, col=exc.offset or 0,
            message=f"syntax error: {exc.msg}",
        )]
    linter = _Linter(path or rel_path, rel_path, source)
    linter.visit(tree)
    linter.finish()
    return sorted(linter.findings, key=lambda f: (f.path, f.line, f.code))


def lint_file(path: str, root: str | None = None) -> list[AstFinding]:
    rel = os.path.relpath(path, root) if root else path
    with open(path, encoding="utf-8") as f:
        return lint_source(f.read(), rel, path)


def lint_paths(paths: list[str]) -> list[AstFinding]:
    """Lint every ``.py`` under each path (files are linted directly)."""
    findings: list[AstFinding] = []
    for p in paths:
        if os.path.isfile(p):
            findings.extend(lint_file(p, root=os.path.dirname(p) or "."))
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    findings.extend(lint_file(os.path.join(dirpath, fn), root=p))
    return findings


def format_findings(findings: list[AstFinding]) -> str:
    if not findings:
        return "astlint: clean"
    lines = [
        f"{f.path}:{f.line}:{f.col}: {f.code} {f.message}" for f in findings
    ]
    lines.append(f"astlint: {len(findings)} finding(s)")
    return "\n".join(lines)


def main(paths: list[str] | None = None) -> int:
    """Entry point shared by ``--ast`` and direct execution.

    ``python src/repro/tracecheck/astlint.py [paths...]`` works without
    the package being importable — the ruff CI step has no jax installed
    and runs this file directly.
    """
    findings = lint_paths(paths or ["src/repro"])
    print(format_findings(findings))
    return 1 if findings else 0


if __name__ == "__main__":  # pragma: no cover
    import sys

    sys.exit(main(sys.argv[1:] or None))
