"""Static per-iteration cost gate: FLOPs / HBM bytes / collectives.

The paper's scaling claims are per-iteration claims: one MWU step costs
O(nnz) work, O(state) memory traffic and exactly the pod plan's
collectives. The runtime benches measure that end to end, but only on
the hardware they run on; this pass recovers the same three counters
*statically* from the compiled program, so a diff that inflates the
per-iteration cost fails CI before anything executes.

How a cell is costed: parse the artifact's compiled HLO
(:func:`repro.tracecheck.hlo_ir.parse_hlo`), locate the top-level
``while`` loops (the MWU iteration loop; batch programs may carry one
per sub-program), pick the heaviest body, and run the roofline
accounting (:func:`repro.utils.hlo.analyze_hlo`) rooted at that body
computation — counting the body **once** while still trip-multiplying
loops nested inside it (line searches). FLOP/byte/collective tables are
the roofline analyzer's; seconds come from
:func:`repro.utils.roofline.static_cost_terms`, so the cost model and
the dry-run roofline can never disagree on op costs.

Gating: :data:`COSTMODEL_BASELINE` (``costmodel_baseline.json`` next to
this module) stores the accepted per-iteration counters per artifact.
:func:`check_costs` emits an error :class:`~repro.tracecheck.rules.Finding`
(rule ``cost-regression``) when a counter grows past its relative
tolerance (:data:`DEFAULT_TOLERANCES`) and a warning when a cell has no
baseline yet (new matrix cells are recorded, not failed). Shrinking
costs never fail — re-run ``python -m repro.tracecheck --matrix
--update-cost-baseline`` to ratchet the baseline down after an
optimization, and commit the file with the diff that earned it.

``COSTMODEL.json`` (``--costmodel-out``) carries every cell's counters
plus the baseline comparison for offline triage.
"""
from __future__ import annotations

import json
import os

from . import hlo_ir

__all__ = [
    "COST_RULE",
    "COSTMODEL_BASELINE",
    "DEFAULT_TOLERANCES",
    "iteration_cost",
    "cost_cells",
    "check_costs",
    "load_cost_baseline",
    "write_cost_baseline",
    "build_costmodel_report",
]

COST_RULE = "cost-regression"

COSTMODEL_BASELINE = os.path.join(os.path.dirname(__file__), "costmodel_baseline.json")

# relative growth allowed per counter before the gate fails. flops and
# bytes tolerate fusion-boundary jitter across jax/XLA versions; the
# collective *count* is exact — one extra psum per iteration is exactly
# the regression class the dist layer's design forbids.
DEFAULT_TOLERANCES = {
    "flops": 0.15,
    "hbm_bytes": 0.25,
    "collective_wire_bytes": 0.10,
    "n_collectives": 0.0,
}

_METRICS = tuple(DEFAULT_TOLERANCES)


def iteration_cost(hlo_text, num_partitions: int = 1) -> dict | None:
    """Per-iteration counters of the heaviest top-level while body.

    Returns None when the program has no top-level ``while`` (loop-free
    kernel artifacts) — such artifacts have no per-iteration cost to
    gate.
    """
    from ..utils.hlo import analyze_hlo
    from ..utils.roofline import static_cost_terms

    mod = hlo_text if hasattr(hlo_text, "comps") else hlo_ir.parse_hlo(hlo_text)
    tops = [w for w in hlo_ir.while_ops(mod) if w["top_level"] and w["body"]]
    if not tops:
        return None
    best = None
    for w in tops:
        rep = analyze_hlo(mod, num_partitions, root=w["body"])
        cand = {
            "flops": rep.flops,
            "dot_flops": rep.dot_flops,
            "fusion_flops": rep.fusion_flops,
            "hbm_bytes": rep.hbm_bytes,
            "collective_wire_bytes": rep.collective_wire_bytes,
            "n_collectives": rep.n_collectives,
            "trip_bound": hlo_ir.trip_count(mod.comps, w["cond"]) if w["cond"] else None,
            "body": w["body"],
        }
        if best is None or (cand["flops"] + cand["hbm_bytes"]) > (
            best["flops"] + best["hbm_bytes"]
        ):
            best = cand
    best["n_top_level_whiles"] = len(tops)
    best["roofline"] = static_cost_terms(
        best["flops"], best["hbm_bytes"], best["collective_wire_bytes"]
    )
    return best


def cost_cells(artifacts) -> dict[str, dict]:
    """{artifact name: per-iteration counters} for compiled artifacts."""
    cells: dict[str, dict] = {}
    for art in artifacts:
        if art.hlo_text is None:
            continue
        parts = getattr(art.plan, "n_devices", 1) if art.plan is not None else 1
        cost = iteration_cost(art.hlo or art.hlo_text, num_partitions=parts)
        if cost is not None:
            cells[art.name] = cost
    return cells


def check_costs(cells: dict, baseline: dict, tolerances: dict | None = None) -> list:
    """Findings for cells whose counters regressed past tolerance.

    One finding per (cell, counter) with key ``<counter>`` so the
    fingerprint (``cost-regression::<cell>::<counter>``) stays stable for
    the baseline allowlist. Cells missing from the cost baseline warn —
    a brand-new matrix cell is recorded by regenerating the baseline,
    not silently gated against nothing.
    """
    from .rules import ERROR, WARNING, Finding

    tolerances = DEFAULT_TOLERANCES if tolerances is None else tolerances
    findings: list[Finding] = []
    for name in sorted(cells):
        cost = cells[name]
        base = baseline.get(name)
        if base is None:
            findings.append(Finding(
                rule=COST_RULE, severity=WARNING, artifact=name, key="missing-baseline",
                message=(
                    "no committed cost baseline for this cell — regenerate "
                    "costmodel_baseline.json (--update-cost-baseline) and commit it"
                ),
            ))
            continue
        for metric, tol in tolerances.items():
            have = float(cost.get(metric, 0.0))
            want = float(base.get(metric, 0.0))
            if have <= want * (1.0 + tol) + 1e-9:
                continue
            growth = have / want - 1.0 if want else float("inf")
            findings.append(Finding(
                rule=COST_RULE, severity=ERROR, artifact=name, key=metric,
                message=(
                    f"per-iteration {metric} grew {growth * 100:.1f}% over the "
                    f"committed baseline ({want:.4g} -> {have:.4g}, tolerance "
                    f"{tol * 100:.0f}%) — the static cost of one MWU step regressed"
                ),
                detail={"metric": metric, "baseline": want, "current": have,
                        "tolerance": tol},
            ))
    return findings


def load_cost_baseline(path: str | None = None) -> dict:
    path = path or COSTMODEL_BASELINE
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f).get("cells", {})


def write_cost_baseline(cells: dict, path: str | None = None) -> str:
    """Persist the gated counters (only) of every cell as the new baseline."""
    path = path or COSTMODEL_BASELINE
    slim = {
        name: {m: cost.get(m, 0) for m in _METRICS} for name, cost in sorted(cells.items())
    }
    with open(path, "w") as f:
        json.dump({"cells": slim}, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def build_costmodel_report(cells: dict, baseline: dict, findings) -> dict:
    """The COSTMODEL.json payload (cells + comparison + gate verdict)."""
    return {
        "cells": {name: dict(cost) for name, cost in sorted(cells.items())},
        "baseline": {name: dict(b) for name, b in sorted(baseline.items())},
        "findings": [f.as_dict() for f in findings],
        "ok": not any(f.severity == "error" for f in findings),
    }
