"""Jaxpr walkers for the tracecheck rules.

The jaxpr is the pre-compilation view of a traced program: primitives
like ``pallas_call``, ``psum`` and ``io_callback`` are still visible as
themselves (after XLA compilation on CPU they disappear into loops,
all-reduces or host custom-calls whose shape is backend-dependent), so
every rule about *which primitives the trace contains* runs here, and
only compiled-artifact facts (trip constants, f64 op survival,
custom-call targets) run on the HLO text IR (:mod:`.hlo_ir`).

The central helper is :func:`iter_eqns`, a recursive walk over every
equation in a jaxpr nest — through ``pjit`` bodies, ``cond`` branches,
``shard_map``/``custom_vmap_call`` call jaxprs, and ``while`` loops —
tagging each equation with whether it sits inside a ``while`` body or
condition (the solver's hot loop).
"""
from __future__ import annotations

from typing import Iterator

from jax.core import ClosedJaxpr, Jaxpr

__all__ = [
    "iter_eqns",
    "find_eqns",
    "count_primitives",
    "sub_jaxprs",
    "COLLECTIVE_PRIMS",
    "CALLBACK_PRIMS",
]

# SPMD collectives a loop body may (or may not) be allowed to contain.
COLLECTIVE_PRIMS = frozenset(
    {
        "psum",
        "pmax",
        "pmin",
        "pbroadcast",
        "ppermute",
        "all_gather",
        "all_to_all",
        "psum_scatter",
        "reduce_scatter",
    }
)

# Host round-trips: every one of these inside the MWU while body stalls
# the device per iteration (the exact class of regression the trace hook
# opt-in exists to contain).
CALLBACK_PRIMS = frozenset(
    {
        "io_callback",
        "pure_callback",
        "python_callback",
        "callback",
        "debug_callback",
        "debug_print",
        "host_callback_call",
        "outside_call",
        "infeed",
        "outfeed",
        "device_put",  # explicit transfers traced into the loop
    }
)


def sub_jaxprs(eqn) -> Iterator[Jaxpr]:
    """Every jaxpr nested in an equation's params (any call-like prim)."""
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else [v]
        for x in vs:
            if isinstance(x, ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, Jaxpr):
                yield x


def iter_eqns(jaxpr: Jaxpr | ClosedJaxpr, in_while: bool = False) -> Iterator[tuple]:
    """Yield ``(eqn, in_while)`` over the whole nest.

    ``in_while`` is True for equations inside any ``while`` body *or
    condition* (a host callback in the condition is just as much a
    per-iteration stall as one in the body).
    """
    if isinstance(jaxpr, ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn, in_while
        sub = in_while or eqn.primitive.name == "while"
        for j in sub_jaxprs(eqn):
            yield from iter_eqns(j, sub)


def find_eqns(jaxpr, name: str, in_while_only: bool = False) -> list:
    """All equations binding primitive ``name`` (optionally loop-scoped)."""
    return [
        eqn
        for eqn, in_w in iter_eqns(jaxpr)
        if eqn.primitive.name == name and (in_w or not in_while_only)
    ]


def count_primitives(jaxpr, names, in_while_only: bool = False) -> dict[str, int]:
    """Occurrence count per primitive name (only names present are keyed)."""
    counts: dict[str, int] = {}
    for eqn, in_w in iter_eqns(jaxpr):
        if in_while_only and not in_w:
            continue
        n = eqn.primitive.name
        if n in names:
            counts[n] = counts.get(n, 0) + 1
    return counts
