"""Differential jaxpr prover: canonicalize two traces and diff them.

The repo makes two parity claims that until now were enforced only by
runtime tests (bit-equal outputs on one seed) and prose:

* **dist-identity** — on a 1-device :class:`~repro.dist.mesh.MeshPlan`,
  ``DistSolver.solve_batch`` traces the *same program* as
  ``Solver.solve_batch`` (the wrappers are skipped entirely, DESIGN
  contract of PR 4). Bit-equal outputs on one input do not prove the
  programs match; an op-for-op structural diff of the canonicalized
  jaxprs does, for every input.
* **backend parity** — ``Solver.solve`` traced under the ``pallas``
  policy may differ from the ``xla`` trace *only inside the dispatched
  kernel regions*: every divergent region must either contain a
  ``pallas_call`` (the kernel side) or consist purely of vector math
  (the XLA reference expression for the same op). Loop structure,
  collectives, callbacks and dtypes must be identical — a refactor that
  perturbs the while body outside a dispatch site fails the gate even
  when both backends still produce correct numbers.

Canonicalization (:func:`canonical_tokens`): alpha-rename variables in
order of first appearance, render avals as ``dtype[shape]``, sort the
operands of commutative primitives, drop trace-incidental params
(names, source info, unhashable backend objects), and flatten nested
jaxprs (while bodies, branches, pjit calls) into the token stream with
structural brackets so a sequence diff aligns loop bodies. Call-like
wrapper eqns that are the *sole* content of a jaxpr (``pjit`` around
``shard_map`` around the body, from jitting) are unwrapped first, which
is what lets the mesh-wrapped DistSolver program be compared op-for-op
against the plain Solver body.

Diffing comes in two granularities. :func:`diff_tokens` aligns flat
token streams (``difflib.SequenceMatcher``) — exact, used for the
all-or-nothing dist-identity check. :func:`hierarchical_regions` aligns
eqn *headers* level by level and recurses into matched containers
(while bodies, cond branches, pjit shells), so a divergence deep inside
a loop body is scoped to that body instead of derailing the global
alignment — that is what lets :func:`check_backend_parity` classify
each divergence by its deep primitive content. Both report through the
standard :class:`~repro.tracecheck.rules.Finding` machinery (rules
``jaxpr-parity-dist`` / ``jaxpr-parity-backend``).
"""
from __future__ import annotations

import difflib
import re
from dataclasses import dataclass

from .jaxpr_scan import CALLBACK_PRIMS, COLLECTIVE_PRIMS
from .rules import ERROR, Finding

__all__ = [
    "canonical_tokens",
    "diff_tokens",
    "hierarchical_regions",
    "DiffRegion",
    "check_dist_identity",
    "check_backend_parity",
    "DIST_PARITY_RULE",
    "BACKEND_PARITY_RULE",
]

DIST_PARITY_RULE = "jaxpr-parity-dist"
BACKEND_PARITY_RULE = "jaxpr-parity-backend"

# primitives whose operand order is mathematically irrelevant; sorting
# them makes `a + b` vs `b + a` canonical-equal
_COMMUTATIVE = frozenset({"add", "mul", "max", "min", "and", "or", "xor", "add_any"})

# call-like wrappers that are transparent when they are a jaxpr's sole
# content: jitting adds a pjit shell, DistSolver adds a shard_map shell
_TRANSPARENT_WRAPPERS = frozenset({"pjit", "shard_map", "closed_call", "core_call", "remat2", "custom_vmap_call"})

# params that vary per trace without changing the program
_DROP_PARAMS = frozenset({
    "name", "source_info", "inline", "keep_unused", "donated_invars",
    "in_shardings", "out_shardings", "in_layouts", "out_layouts",
    "resource_env", "compiler_options_kvs", "ctx_mesh", "mesh",
    "name_and_src_info", "debug_info", "interpret", "backend", "device",
})

_DISPATCH_PRIMS = frozenset({"pallas_call", "custom_vmap_call"})


def _jaxpr_of(x):
    return x.jaxpr if hasattr(x, "jaxpr") else x


def _unwrap(jaxpr):
    """Descend through sole-eqn transparent wrappers (pjit/shard_map shells)."""
    jaxpr = _jaxpr_of(jaxpr)
    while len(jaxpr.eqns) == 1 and jaxpr.eqns[0].primitive.name in _TRANSPARENT_WRAPPERS:
        eqn = jaxpr.eqns[0]
        inner = None
        for v in eqn.params.values():
            if hasattr(v, "jaxpr") or hasattr(v, "eqns"):
                inner = _jaxpr_of(v)
                break
        if inner is None:
            break
        jaxpr = inner
    return jaxpr


def _aval_str(v) -> str:
    aval = getattr(v, "aval", None)
    if aval is None:
        return "?"
    s = str(aval)
    # strip weak-type / named-shape noise that varies across jax versions
    return re.sub(r"\{[^}]*\}", "", s)


class _Namer:
    def __init__(self):
        self.names: dict[int, str] = {}

    def __call__(self, v) -> str:
        if type(v).__name__ == "Literal" or hasattr(v, "val"):
            val = getattr(v, "val", None)
            try:
                size = val.size  # 0-d array literal
            except AttributeError:
                size = 1
            if size <= 1:
                return f"lit({val})"
            return f"lit[{_aval_str(v)}]"
        key = id(v)
        if key not in self.names:
            self.names[key] = f"v{len(self.names)}"
        return self.names[key]


def _fmt_param(v) -> str:
    if isinstance(v, (type(None), bool, int, float, str)):
        return repr(v)
    if isinstance(v, (tuple, list)):
        return "(" + ",".join(_fmt_param(x) for x in v) + ")"
    if isinstance(v, dict):
        return "{" + ",".join(f"{k}:{_fmt_param(x)}" for k, x in sorted(v.items())) + "}"
    if hasattr(v, "dtype") and hasattr(v, "shape"):
        return f"arr[{getattr(v, 'dtype', '?')}{tuple(getattr(v, 'shape', ()))}]"
    try:
        import numpy as _np

        if isinstance(v, _np.dtype):
            return str(v)
    except ImportError:  # pragma: no cover
        pass
    return f"<{type(v).__name__}>"


def _emit(jaxpr, namer: _Namer, out: list[str]) -> None:
    jaxpr = _jaxpr_of(jaxpr)
    for v in list(getattr(jaxpr, "constvars", ())) + list(jaxpr.invars):
        namer(v)
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        ins = [namer(v) for v in eqn.invars]
        if prim in _COMMUTATIVE:
            ins = sorted(ins)
        subs = []
        params = []
        for k in sorted(eqn.params):
            if k in _DROP_PARAMS:
                continue
            v = eqn.params[k]
            vs = v if isinstance(v, (tuple, list)) else (v,)
            if any(hasattr(x, "eqns") or hasattr(x, "jaxpr") for x in vs):
                subs.extend((k, x) for x in vs if hasattr(x, "eqns") or hasattr(x, "jaxpr"))
                continue
            params.append(f"{k}={_fmt_param(v)}")
        outs = [f"{namer(v)}:{_aval_str(v)}" for v in eqn.outvars]
        out.append(f"{prim}[{' '.join(params)}]({','.join(ins)})->({','.join(outs)})")
        for k, sub in subs:
            out.append(f"{prim}:{k}{{")
            # sub-jaxpr variables are a fresh scope
            _emit(sub, _Namer(), out)
            out.append(f"}}{prim}:{k}")


def canonical_tokens(jaxpr, *, unwrap: bool = True) -> list[str]:
    """Canonical token stream of a (Closed)Jaxpr (see module docstring)."""
    jaxpr = _unwrap(jaxpr) if unwrap else _jaxpr_of(jaxpr)
    out: list[str] = []
    _emit(jaxpr, _Namer(), out)
    return out


@dataclass
class DiffRegion:
    """One divergent run between two canonical token streams."""

    kind: str  # replace | delete | insert
    a_start: int
    a_tokens: list[str]
    b_start: int
    b_tokens: list[str]

    def prims(self, side: str) -> set[str]:
        toks = self.a_tokens if side == "a" else self.b_tokens
        out = set()
        for t in toks:
            m = re.match(r"\}?([\w.\-]+?)(?:\[|:|\{)", t)
            if m:
                out.add(m.group(1))
        return out

    def summary(self, n: int = 3) -> str:
        def clip(toks):
            shown = [t[:90] for t in toks[:n]]
            more = f" …+{len(toks) - n}" if len(toks) > n else ""
            return "; ".join(shown) + more

        return f"a[{self.a_start}]: {clip(self.a_tokens) or '∅'}  <->  b[{self.b_start}]: {clip(self.b_tokens) or '∅'}"


def diff_tokens(a: list[str], b: list[str]) -> list[DiffRegion]:
    """Non-equal opcode runs of a sequence alignment of two token streams."""
    sm = difflib.SequenceMatcher(a=a, b=b, autojunk=False)
    regions = []
    for tag, i1, i2, j1, j2 in sm.get_opcodes():
        if tag == "equal":
            continue
        regions.append(DiffRegion(
            kind=tag, a_start=i1, a_tokens=a[i1:i2], b_start=j1, b_tokens=b[j1:j2],
        ))
    return regions


# ------------------------------------------------------------ the checks --
def _finding(rule, artifact, message, *, key="", severity=ERROR, **detail) -> Finding:
    return Finding(rule=rule, severity=severity, artifact=artifact,
                   message=message, key=key, detail=detail)


def check_dist_identity(jaxpr_solver, jaxpr_dist, artifact: str) -> list[Finding]:
    """Prove an identity-plan DistSolver trace ≡ the plain Solver trace.

    Both jaxprs are canonicalized (the dist side's pjit/shard_map shells
    unwrap) and must be token-for-token equal; any divergence is an
    error finding carrying the first few divergent regions.
    """
    a = canonical_tokens(jaxpr_solver)
    b = canonical_tokens(jaxpr_dist)
    regions = diff_tokens(a, b)
    if not regions:
        return []
    head = regions[:4]
    msg = (
        f"identity-MeshPlan DistSolver trace diverges from Solver in "
        f"{len(regions)} region(s) — the 1-device parity contract is broken: "
        + " | ".join(r.summary() for r in head)
    )
    return [_finding(
        DIST_PARITY_RULE, artifact, msg, key="diverged",
        n_regions=len(regions),
        regions=[{"kind": r.kind, "a_start": r.a_start, "b_start": r.b_start,
                  "a": r.a_tokens[:6], "b": r.b_tokens[:6]} for r in head],
    )]


# -- hierarchical diff (backend parity) ------------------------------------
# Containers recurse level-by-level so a divergence deep inside a while
# body is scoped to that body instead of derailing the global alignment.
# Their level-header deliberately drops invars and const-count params:
# the pallas path changes which closure consts a loop body captures, but
# the carried state (outvars) must match for the loops to be "the same
# loop". Transparent containers (pjit shells jnp emits, cond branches of
# one op's implementation, custom_vmap wrappers) are not structural by
# themselves — only their *deep* content (loops, collectives, callbacks)
# is held against a region.
_CLASSIFY_STRUCTURAL = (
    frozenset({"while", "scan"}) | COLLECTIVE_PRIMS | CALLBACK_PRIMS
)


def _sub_jaxprs_of(eqn) -> list:
    subs = []
    for k in sorted(eqn.params):
        v = eqn.params[k]
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for x in vs:
            if hasattr(x, "eqns") or hasattr(x, "jaxpr"):
                subs.append(_jaxpr_of(x))
    return subs


def _deep_prims(eqns) -> set[str]:
    out: set[str] = set()
    stack = list(eqns)
    while stack:
        eqn = stack.pop()
        out.add(eqn.primitive.name)
        for sub in _sub_jaxprs_of(eqn):
            stack.extend(sub.eqns)
    return out


def _level_header(eqn, namer: _Namer) -> str:
    prim = eqn.primitive.name
    outs = ",".join(_aval_str(v) for v in eqn.outvars)
    if _sub_jaxprs_of(eqn):
        return f"{prim}->({outs})"
    ins = [namer(v) for v in eqn.invars]
    if prim in _COMMUTATIVE:
        ins = sorted(ins)
    named_outs = ",".join(f"{namer(v)}:{_aval_str(v)}" for v in eqn.outvars)
    return f"{prim}({','.join(ins)})->({named_outs})"


def hierarchical_regions(jaxpr_a, jaxpr_b) -> list[tuple[str, "DiffRegion"]]:
    """(path, region) pairs of a container-scoped structural diff.

    Aligns the two eqn sequences level by level; matched container pairs
    (same primitive, same output avals) recurse into their sub-jaxprs
    with the path extended (``while/0`` = first sub-jaxpr of the matched
    while). Regions carry raw eqn lists so callers can classify them by
    deep primitive content.
    """
    out: list[tuple[str, DiffRegion]] = []

    def walk(ja, jb, path):
        ea, eb = list(_jaxpr_of(ja).eqns), list(_jaxpr_of(jb).eqns)
        na, nb = _Namer(), _Namer()
        for v in list(getattr(_jaxpr_of(ja), "constvars", ())) + list(_jaxpr_of(ja).invars):
            na(v)
        for v in list(getattr(_jaxpr_of(jb), "constvars", ())) + list(_jaxpr_of(jb).invars):
            nb(v)
        ha = [_level_header(e, na) for e in ea]
        hb = [_level_header(e, nb) for e in eb]
        sm = difflib.SequenceMatcher(a=ha, b=hb, autojunk=False)
        for tag, i1, i2, j1, j2 in sm.get_opcodes():
            if tag == "equal":
                for ea_i, eb_i in zip(ea[i1:i2], eb[j1:j2]):
                    sa, sb = _sub_jaxprs_of(ea_i), _sub_jaxprs_of(eb_i)
                    if len(sa) != len(sb):
                        out.append((path, DiffRegion(
                            "replace", i1, [f"{ea_i.primitive.name}:{len(sa)} sub-jaxprs"],
                            j1, [f"{eb_i.primitive.name}:{len(sb)} sub-jaxprs"],
                        )))
                        continue
                    for k, (xa, xb) in enumerate(zip(sa, sb)):
                        walk(xa, xb, f"{path}/{ea_i.primitive.name}.{k}")
            else:
                r = DiffRegion(tag, i1, ha[i1:i2], j1, hb[j1:j2])
                r.a_eqns = ea[i1:i2]  # raw eqns ride along for deep classification
                r.b_eqns = eb[j1:j2]
                out.append((path, r))

    walk(_unwrap(jaxpr_a), _unwrap(jaxpr_b), "")
    return out


def check_backend_parity(jaxpr_xla, jaxpr_pallas, artifact: str) -> list[Finding]:
    """The pallas trace may differ from xla only inside dispatch regions.

    Every divergent region must be explainable by the kernel dispatch:
    one side (deep-)contains a ``pallas_call``/``custom_vmap_call``, or
    both sides are pure vector math (the two implementations of one
    dispatched op). A region whose deep content touches structural
    primitives (loops, collectives, callbacks) on either side is an
    error — the backends no longer run the same algorithm.
    """
    regions = hierarchical_regions(jaxpr_xla, jaxpr_pallas)
    bad = []
    for path, r in regions:
        da = _deep_prims(getattr(r, "a_eqns", []))
        db = _deep_prims(getattr(r, "b_eqns", []))
        if (da | db) & _DISPATCH_PRIMS:
            continue  # the dispatched kernel region itself
        structural = (da | db) & _CLASSIFY_STRUCTURAL
        if structural:
            bad.append((path, r, sorted(structural)))
    if not bad:
        return []
    head = bad[:4]
    msg = (
        f"{len(bad)} pallas-vs-xla divergence region(s) outside the "
        "dispatched kernel regions touch structural primitives "
        f"({sorted(set().union(*(set(s) for _, _, s in head)))}) — the two "
        "backends no longer trace the same algorithm: "
        + " | ".join(f"at {p or '<top>'}: {r.summary()}" for p, r, _ in head)
    )
    return [_finding(
        BACKEND_PARITY_RULE, artifact, msg, key="structural-drift",
        n_regions=len(regions), n_bad=len(bad),
        regions=[{"path": p, "kind": r.kind, "prims": s,
                  "a": r.a_tokens[:6], "b": r.b_tokens[:6]} for p, r, s in head],
    )]
