"""Static jaxpr/HLO analysis gate for the solver's performance invariants.

The paper's speedups live or die on per-iteration primitive cost: fused
vector kernels on the hot path, no host round-trips inside the MWU
``while`` loop, exactly the declared collectives per pod plan, a dtype
that never silently widens. ``repro.tracecheck`` checks all of that
*statically* — it lowers every hot entry point (``Solver.solve`` /
``solve_batch`` per family, lpserve dispatch keys, ``DistSolver`` mesh
plans, each Pallas kernel), inspects the jaxpr and optionally the
compiled HLO, and fails CI when an invariant regresses.

Layout:

* :mod:`.hlo_ir`     — shared textual-HLO parser (also feeds
  :mod:`repro.utils.hlo`'s roofline analyzer);
* :mod:`.jaxpr_scan` — recursive jaxpr walkers with while-loop scoping;
* :mod:`.rules`      — ``Rule`` / ``Finding`` framework + the six
  default rules (see its docstring for the rule set and how to add one);
* :mod:`.capture`    — AOT capture of each entry point via the solver
  lowering hooks (nothing is executed);
* :mod:`.matrix`     — the family × backend × mesh-plan sweep, shared
  with ``benchmarks/run.py``;
* :mod:`.report`     — baseline allowlist + ``TRACECHECK.json``;
* CLI: ``python -m repro.tracecheck --matrix`` (see ``--help``).

Intentional deviations are recorded per-fingerprint in
``baseline.json`` (``{"allow": ["rule::artifact::key", ...]}``) rather
than by disabling rules — see :mod:`.report`.

Heavy submodules (capture pulls in api/dist/lpserve and jax) are
imported lazily; importing :mod:`repro.tracecheck` itself stays cheap.
"""
from .rules import ERROR, WARNING, Finding, Rule, TraceArtifact, run_rules

__all__ = [
    "ERROR",
    "WARNING",
    "Finding",
    "Rule",
    "TraceArtifact",
    "run_rules",
]
