"""Static analysis gate for the solver's performance invariants.

The paper's speedups live or die on per-iteration primitive cost: fused
vector kernels on the hot path, no host round-trips inside the MWU
``while`` loop, exactly the declared collectives per pod plan, a dtype
that never silently widens. ``repro.tracecheck`` checks all of that
*statically*, in three passes:

1. **AST lint** (:mod:`.astlint`, ``--ast``) — source-level RPR rule
   codes catching the patterns that *produce* trace regressions
   (backend reads inside jitted bodies, Python branches on tracers,
   hard-coded f64, stray io_callbacks, unhashable static args, raw
   DeprecationWarnings). Pure stdlib, runs in the dependency-free lint
   CI step; suppression is per line (``# repro: noqa[RPR00x]``).
2. **trace rules + jaxpr parity** (:mod:`.rules`, :mod:`.diff`,
   ``--matrix``) — lowers every hot entry point (``Solver.solve`` /
   ``solve_batch`` per family, lpserve dispatch keys, ``DistSolver``
   mesh plans, each Pallas kernel), lints jaxpr + compiled HLO, and
   *proves* the two parity contracts differentially: pallas-vs-xla
   traces differ only inside dispatched kernel regions, and an
   identity-plan ``DistSolver`` trace is op-for-op the plain ``Solver``
   trace.
3. **cost model** (:mod:`.costmodel`) — static per-iteration
   FLOP/HBM-byte/collective counters of every compiled cell, extracted
   from the top-level while body and gated against the committed
   ``costmodel_baseline.json`` with relative tolerances
   (``COSTMODEL.json`` artifact).

Layout:

* :mod:`.astlint`    — stdlib AST lint (RPR001–RPR006);
* :mod:`.hlo_ir`     — shared textual-HLO parser (also feeds
  :mod:`repro.utils.hlo`'s roofline analyzer);
* :mod:`.jaxpr_scan` — recursive jaxpr walkers with while-loop scoping;
* :mod:`.rules`      — ``Rule`` / ``Finding`` framework + the six
  default rules (see its docstring for the rule set and how to add one);
* :mod:`.diff`       — canonicalized jaxpr differ + the parity checks;
* :mod:`.costmodel`  — per-iteration cost cells + baseline gate;
* :mod:`.capture`    — AOT capture of each entry point via the solver
  lowering hooks (nothing is executed);
* :mod:`.matrix`     — the family × backend × mesh-plan sweep, shared
  with ``benchmarks/run.py``;
* :mod:`.report`     — baseline allowlist + ``TRACECHECK.json`` +
  ``--prune-baseline``;
* CLI: ``python -m repro.tracecheck --matrix`` / ``--ast`` (see
  ``--help``) and ``tracecheck/README.md`` for the full rule catalog.

Intentional deviations are recorded per-fingerprint in
``baseline.json`` (``{"allow": ["rule::artifact::key", ...]}``) rather
than by disabling rules — see :mod:`.report`.

Everything importing jax (rules/capture/diff) loads lazily via PEP 562
so ``import repro.tracecheck`` — and the ``--ast`` CLI path — works in
environments without jax installed.
"""
from __future__ import annotations

__all__ = [
    "ERROR",
    "WARNING",
    "Finding",
    "Rule",
    "TraceArtifact",
    "run_rules",
]


def __getattr__(name):
    if name in __all__:
        from . import rules

        return getattr(rules, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
