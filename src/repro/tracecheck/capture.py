"""Capture hot-entry-point traces as :class:`TraceArtifact`s.

One function per entry point class. All captures are *ahead-of-time*:
the program is traced (``jax.make_jaxpr``) and optionally compiled
(``.lower().compile().as_text()``) but never executed, using the
lowering hooks on :class:`repro.api.Solver` / :class:`repro.dist.DistSolver`
/ :class:`repro.lpserve.LPEngine` and :func:`repro.core.mwu.lower`.

Expectations are computed here, from the same host-side facts the real
dispatch uses: the resolved :class:`~repro.kernels.dispatch.KernelPolicy`
(pallas in the loop only on unbatched paths — vmapped lanes take the
custom_vmap XLA rule by design), the :class:`~repro.dist.mesh.MeshPlan`
(two ``psum`` + one ``pmax`` per iteration under a pod-sharded plan,
nothing under identity plans), and the problem's solve dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import dispatch as _kd
from .rules import TraceArtifact

__all__ = [
    "build_problem",
    "solve_dtype",
    "capture_case",
    "KERNEL_OPS",
    "FAMILIES",
]

# Small-but-not-degenerate capture graphs: every family's operator zoo
# member appears, line searches run several probes, masks exercise the
# masked smoothing paths (gen-match).
_GRAPH_SHAPE = (24, 60)  # (n_vertices, n_edges) for erdos captures

FAMILIES = ("match", "vcover", "dense-sub", "gen-match")

KERNEL_OPS = ("gather", "softmax", "probe", "axpy")


def build_problem(family: str):
    """A tiny representative :class:`~repro.api.Problem` of ``family``."""
    from ..graphs import generators, problems

    n, m = _GRAPH_SHAPE
    g = generators.erdos(n, m, seed=7)
    if family == "gen-match":
        lb = np.zeros(g.n)
        ub = np.full(g.n, 2.0)
        return problems.generalized_matching_problem(g, lb, ub)
    return problems.build(family, g)


def _mid_bound(problem) -> float | None:
    if problem.bound_mode == "none":
        return None
    lo, hi = float(problem.lo), float(problem.hi)
    return lo * (hi / max(lo, 1e-300)) ** 0.5


def solve_dtype(problem, bound=None) -> str:
    """The dtype the MWU driver will run this problem in (mirrors _run_inner)."""
    P, C, _, _ = problem.instantiate(bound)
    dt = jnp.promote_types(P.colmax().dtype, C.colmax().dtype)
    dt = dt if jnp.issubdtype(dt, jnp.floating) else jnp.float32
    return jnp.dtype(dt).name


def _base_expect(policy, opts, dtype, *, pallas_in_loop=False, collectives=None, traced=False):
    return {
        "traced": traced,
        "pallas_in_loop": pallas_in_loop,
        "collectives": dict(collectives or {}),
        "dtype": dtype,
        "max_iter": opts.max_iter,
    }


_POD_COLLECTIVES = {"psum": 2, "pmax": 1}  # dy, dz completions + max(d)


def capture_case(case) -> TraceArtifact | None:
    """Build the artifact for one matrix :class:`~repro.tracecheck.matrix.Case`.

    Returns None when the case cannot run in this process (a mesh plan
    wider than the visible device set) — the caller reports it skipped.
    """
    if case.entry == "kernel":
        return _capture_kernel(case)

    from ..api.solver import Solver
    from ..core.mwu import MWUOptions

    opts = MWUOptions(kernel_backend=case.backend)
    policy = _kd.resolve(case.backend)
    problem = build_problem(case.family)
    bound = _mid_bound(problem)
    dtype = solve_dtype(problem, bound)

    if case.entry in ("solve", "solve_traced"):
        solver = Solver(opts)
        traced = case.entry == "solve_traced"
        jaxpr = solver.jaxpr_feasible(problem, bound, trace=traced)
        hlo_text = None
        if case.hlo:
            hlo_text = solver.lower_feasible(problem, bound, trace=traced).compile().as_text()
        expect = _base_expect(
            policy, opts, dtype,
            pallas_in_loop=policy.backend == "pallas", traced=traced,
        )
        return TraceArtifact(
            name=case.name, jaxpr=jaxpr, hlo_text=hlo_text,
            policy=policy, opts=opts, expect=expect,
        )

    if case.entry == "solve_batch":
        solver = Solver(opts)
        bounds = _batch_bounds(problem, 2)
        jaxpr = solver.jaxpr_batch(problem, bounds)
        hlo_text = None
        if case.hlo:
            hlo_text = solver.lower_batch(problem, bounds).compile().as_text()
        # vmapped lanes take the custom_vmap XLA batch rule: no pallas
        expect = _base_expect(policy, opts, dtype, pallas_in_loop=False)
        return TraceArtifact(
            name=case.name, jaxpr=jaxpr, hlo_text=hlo_text,
            policy=policy, opts=opts, expect=expect,
        )

    if case.entry == "dist":
        from ..dist.mesh import MeshPlan
        from ..dist.shard import pod_mode
        from ..dist.solver import DistSolver

        plan = MeshPlan(pod=case.pod, data=case.data)
        if plan.n_devices > len(jax.devices()):
            return None
        solver = DistSolver(opts, plan=plan)
        # identity plans use the same batch width as the solve_batch
        # cells so the jaxpr parity prover can diff the two traces
        # op-for-op; sharded plans keep B == data (no-vmap fast path)
        width = 2 if plan.n_devices == 1 else plan.data
        bounds = _batch_bounds(problem, width)
        mode = pod_mode(problem) if plan.pod > 1 else None
        jaxpr = solver.jaxpr_batch(problem, bounds)
        hlo_text = None
        if case.hlo:
            hlo_text = solver.lower_batch(problem, bounds).compile().as_text()
        # B == data puts multi-device plans on the no-vmap fast path, so
        # the kernel pack stays active there; identity plans vmap.
        no_vmap = plan.n_devices > 1
        expect = _base_expect(
            policy, opts, dtype,
            pallas_in_loop=policy.backend == "pallas" and no_vmap,
            collectives=_POD_COLLECTIVES if plan.pod > 1 else None,
        )
        return TraceArtifact(
            name=case.name, jaxpr=jaxpr, hlo_text=hlo_text, policy=policy,
            opts=opts, plan=plan, pod_mode=mode, expect=expect,
        )

    if case.entry == "lpserve":
        from ..lpserve import LPEngine, LPServeConfig

        eng = LPEngine(LPServeConfig(opts=opts, lanes=case.lanes))
        for seed in (1, 2):
            from ..graphs import generators, problems

            g = generators.erdos(*_GRAPH_SHAPE, seed=seed)
            if case.family == "gen-match":
                p = problems.generalized_matching_problem(
                    g, np.zeros(g.n), np.full(g.n, 2.0)
                )
            else:
                p = problems.build(case.family, g)
            eng.submit(p)
        arts = []
        for key, (stacked, bounds) in eng.audit_launches().items():
            jaxpr = eng.solver.jaxpr_batch(stacked, bounds, batched_problem=True)
            hlo_text = None
            if case.hlo:
                hlo_text = (
                    eng.solver.lower_batch(stacked, bounds, batched_problem=True)
                    .compile()
                    .as_text()
                )
            template = jax.tree.map(lambda a: jnp.asarray(a)[0], stacked)
            expect = _base_expect(
                policy, opts, solve_dtype(template, float(np.asarray(bounds)[0])),
                pallas_in_loop=False,
            )
            arts.append(TraceArtifact(
                name=f"{case.name}[{key[0]}/{key[4]}]", jaxpr=jaxpr,
                hlo_text=hlo_text, policy=policy, opts=opts, expect=expect,
            ))
        return arts

    raise ValueError(f"unknown tracecheck entry {case.entry!r}")


def _batch_bounds(problem, width: int):
    b = _mid_bound(problem)
    if b is None:
        return jnp.ones((width,))
    lo, hi = float(problem.lo), float(problem.hi)
    r = hi / max(lo, 1e-300)
    return jnp.asarray([lo * r ** ((k + 1) / (width + 1)) for k in range(width)])


# ----------------------------------------------------------- raw kernels --
def _capture_kernel(case) -> TraceArtifact:
    """Trace one Pallas kernel abstractly at its dispatch-gate limit shape.

    Shapes are ``jax.ShapeDtypeStruct``s so nothing is allocated: the
    VMEM rule sees the BlockSpecs exactly as a real TPU launch at the
    largest size the per-op gate admits.
    """
    from ..kernels.axpy_reduce.kernel import axpy_reduce_pallas
    from ..kernels.incidence_gather.kernel import incidence_gather_pallas
    from ..kernels.linesearch_probe.kernel import linesearch_probe_pallas
    from ..kernels.softmax_weights.kernel import softmax_weights_pallas

    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    n_limit = _kd.vmem_vertex_limit(f32)
    m = 1 << 20  # streamed constraint-space length: VMEM use is grid-invariant

    if case.op == "gather":
        fn = lambda u, v, w: incidence_gather_pallas(u, v, w, interpret=True)
        args = (sds((4096,), jnp.int32), sds((4096,), jnp.int32), sds((n_limit,), f32))
    elif case.op == "softmax":
        fn = lambda v, eta: softmax_weights_pallas(v, eta, sign=1.0, interpret=True)
        args = (sds((m,), f32), sds((), f32))
    elif case.op == "probe":
        fn = lambda y, dy, a, eta: linesearch_probe_pallas(y, dy, a, eta, sign=1.0, interpret=True)
        args = (sds((m,), f32), sds((m,), f32), sds((), f32), sds((), f32))
    elif case.op == "axpy":
        fn = lambda y, dy, a: axpy_reduce_pallas(y, dy, a, interpret=True)
        args = (sds((m,), f32), sds((m,), f32), sds((), f32))
    else:
        raise ValueError(f"unknown kernel op {case.op!r}")

    jaxpr = jax.make_jaxpr(fn)(*args)
    policy = _kd.KernelPolicy("pallas", interpret=True)
    return TraceArtifact(
        name=case.name, jaxpr=jaxpr, policy=policy,
        expect={"pallas_anywhere": True, "dtype": "float32", "collectives": {}},
    )
