"""Dispatcher for the fused line-search probe.

Backend resolution happens host-side in the wrapper (not at trace time
inside the jit); see ``repro.kernels.dispatch``.
"""
from functools import partial

import jax

from ..dispatch import resolve_impl
from .kernel import linesearch_probe_pallas
from .ref import linesearch_probe_ref


@partial(jax.jit, static_argnames=("sign", "impl", "interpret"))
def _linesearch_probe_jit(y, dy, alpha, eta, sign: float, impl: str, interpret: bool):
    if impl == "pallas":
        return linesearch_probe_pallas(y, dy, alpha, eta, sign=sign, interpret=interpret)
    return linesearch_probe_ref(y, dy, alpha, eta, sign)


def linesearch_probe(y, dy, alpha, eta, sign: float = 1.0, impl: str = "auto"):
    """(lse, slope, min_v) for a = sign*eta*(y + alpha*dy), one fused sweep."""
    impl, interpret = resolve_impl("probe", impl, n=y.shape[0], dtype=y.dtype)
    return _linesearch_probe_jit(y, dy, alpha, eta, sign, impl, interpret)
