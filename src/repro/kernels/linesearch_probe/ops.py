"""Jitted dispatcher for the fused line-search probe."""
from functools import partial

import jax

from .kernel import linesearch_probe_pallas
from .ref import linesearch_probe_ref


@partial(jax.jit, static_argnames=("sign", "impl"))
def linesearch_probe(y, dy, alpha, eta, sign: float = 1.0, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        interpret = jax.default_backend() != "tpu"
        return linesearch_probe_pallas(y, dy, alpha, eta, sign=sign, interpret=interpret)
    return linesearch_probe_ref(y, dy, alpha, eta, sign)
