"""Pure-jnp oracle for linesearch_probe (dtype-preserving)."""
import jax.numpy as jnp


def linesearch_probe_ref(y, dy, alpha, eta, sign: float = 1.0):
    v = y + alpha * dy
    a = (sign * eta) * v
    m = jnp.max(a)
    e = jnp.exp(a - m)
    s = jnp.sum(e)
    lse = m + jnp.log(s)
    slope = jnp.sum(e * dy) / s
    return lse, slope, jnp.min(v)
