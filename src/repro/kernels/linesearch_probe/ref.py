"""Pure-jnp oracle for linesearch_probe."""
import jax.numpy as jnp


def linesearch_probe_ref(y, dy, alpha, eta, sign: float = 1.0):
    y = y.astype(jnp.float32)
    dy = dy.astype(jnp.float32)
    v = y + alpha.astype(jnp.float32) * dy
    a = (sign * eta) * v
    m = jnp.max(a)
    e = jnp.exp(a - m)
    s = jnp.sum(e)
    lse = m + jnp.log(s)
    slope = jnp.sum(e * dy) / s
    return lse, slope, jnp.min(v)
