"""Pallas TPU kernel: fused line-search probe (paper Alg. 3 inner loop).

For one probe point alpha, over a constraint vector pair (y, dy):

    a_i  = sign * eta * (y_i + alpha * dy_i)
    lse  = logsumexp(a)                      -> Psi/Phi pieces
    t    = sum softmax(a)_i * dy_i           -> Psi'/Phi' (Newton slope)
    mn   = min(y_i + alpha * dy_i)           -> completion test

Everything a binary-search or Newton probe needs, in ONE sweep of
(y, dy) — the unfused XLA path reads both vectors 3-4 times. The paper
identifies exactly this "search" vector work as 20-50% of runtime
(Fig. 5a); this kernel is its TPU counterpart, and
``core.stepsize.make_probe_fn`` routes every probe through it when the
dispatch layer selects the pallas backend.

Online update per tile (flash-style):
    m' = max(m, max(a));  c = exp(m - m')
    s' = s*c + sum exp(a - m');  t' = t*c + sum exp(a - m') * dy
(final t/s = <softmax(a), dy>, computed by the host wrapper).

Arithmetic runs in the input dtype (f64 stays f64 in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
SUBLANES = 8
TILE = SUBLANES * LANES

_POS = 1e30
_NEG = -1e30


def _probe_kernel(n, scal_ref, y_ref, dy_ref, out_ref, acc_ref):
    """scal = [sign*eta, alpha]; out = [m, lse, t_scaled, min_v]."""
    i = pl.program_id(0)
    nt = pl.num_programs(0)
    dt = acc_ref.dtype

    @pl.when(i == 0)
    def _init():
        acc_ref[0] = jnp.asarray(_NEG, dt)  # running max m
        acc_ref[1] = jnp.asarray(0.0, dt)  # running s
        acc_ref[2] = jnp.asarray(0.0, dt)  # running t (softmax-weighted dy)
        acc_ref[3] = jnp.asarray(_POS, dt)  # running min of v

    se = scal_ref[0]
    alpha = scal_ref[1]
    y = y_ref[...]
    dy = dy_ref[...]
    v = y + alpha * dy
    a = v * se
    idx = jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 0) * LANES + jax.lax.broadcasted_iota(
        jnp.int32, (SUBLANES, LANES), 1
    )
    valid = (i * TILE + idx) < n
    a = jnp.where(valid, a, jnp.asarray(_NEG, dt))

    m_old, s_old, t_old = acc_ref[0], acc_ref[1], acc_ref[2]
    m_new = jnp.maximum(m_old, jnp.max(a))
    c = jnp.exp(m_old - m_new)
    e = jnp.exp(a - m_new)
    acc_ref[0] = m_new
    acc_ref[1] = s_old * c + jnp.sum(e)
    acc_ref[2] = t_old * c + jnp.sum(e * jnp.where(valid, dy, jnp.zeros((), dt)))
    acc_ref[3] = jnp.minimum(acc_ref[3], jnp.min(jnp.where(valid, v, jnp.asarray(_POS, dt))))

    @pl.when(i == nt - 1)
    def _fin():
        out_ref[0] = acc_ref[0]
        out_ref[1] = acc_ref[0] + jnp.log(acc_ref[1])  # lse
        out_ref[2] = acc_ref[2] / acc_ref[1]  # <softmax, dy>
        out_ref[3] = acc_ref[3]  # min(y + alpha dy)


def linesearch_probe_pallas(y, dy, alpha, eta, sign: float = 1.0, interpret: bool = True):
    """Returns (lse, slope, min_v) for a = sign*eta*(y + alpha*dy)."""
    n = y.shape[0]
    dt = y.dtype
    nt = max(1, (n + TILE - 1) // TILE)
    pad = nt * TILE - n
    yp = jnp.pad(y, (0, pad)).reshape(nt * SUBLANES, LANES)
    dp = jnp.pad(dy.astype(dt), (0, pad)).reshape(nt * SUBLANES, LANES)
    scal = jnp.stack([jnp.asarray(sign, dt) * eta.astype(dt), alpha.astype(dt)])
    out = pl.pallas_call(
        functools.partial(_probe_kernel, n),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((4,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((4,), dt),
        scratch_shapes=[pltpu.SMEM((4,), dt)],
        interpret=interpret,
    )(scal, yp, dp)
    return out[1], out[2], out[3]
