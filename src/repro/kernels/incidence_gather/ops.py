"""Jitted dispatcher for the incidence gather (M^T w)."""
from functools import partial

import jax

from .kernel import incidence_gather_pallas
from .ref import incidence_gather_ref

# beyond this vertex count w no longer fits VMEM single-block
_VMEM_VERTEX_LIMIT = 3_000_000


@partial(jax.jit, static_argnames=("impl",))
def incidence_gather(u, v, w, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if (jax.default_backend() == "tpu" and w.shape[0] <= _VMEM_VERTEX_LIMIT) else "xla"
    if impl == "pallas":
        interpret = jax.default_backend() != "tpu"
        return incidence_gather_pallas(u, v, w, interpret=interpret)
    return incidence_gather_ref(u, v, w)
