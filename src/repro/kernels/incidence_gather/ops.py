"""Dispatcher for the incidence gather (M^T w).

Backend resolution is hoisted OUT of the jitted inner function: the old
version keyed on ``jax.default_backend()`` at trace time inside a
``@jax.jit``, so a CPU→TPU device switch could keep serving the stale
cached choice. Now the host-side wrapper resolves ``impl`` per call (via
``repro.kernels.dispatch.resolve_impl``, which also applies the
``VMEM_VERTEX_LIMIT`` gate and the ``REPRO_KERNEL_BACKEND`` override)
and the concrete choice is a static argument of the jitted inner.
"""
from functools import partial

import jax

from ..dispatch import resolve_impl
from .kernel import incidence_gather_pallas
from .ref import incidence_gather_ref


@partial(jax.jit, static_argnames=("impl", "interpret"))
def _incidence_gather_jit(u, v, w, impl: str, interpret: bool):
    if impl == "pallas":
        return incidence_gather_pallas(u, v, w, interpret=interpret)
    return incidence_gather_ref(u, v, w)


def incidence_gather(u, v, w, impl: str = "auto"):
    """g[e] = w[u[e]] + w[v[e]] in w's dtype; zero for padded edge slots."""
    impl, interpret = resolve_impl("gather", impl, n=w.shape[0], dtype=w.dtype)
    return _incidence_gather_jit(u, v, w, impl, interpret)
