"""Pure-jnp oracle for incidence_gather."""
import jax.numpy as jnp


def incidence_gather_ref(u, v, w):
    w = w.astype(jnp.float32)
    return w[u] + w[v]
