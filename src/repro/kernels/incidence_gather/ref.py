"""Pure-jnp oracle for incidence_gather (dtype-preserving)."""


def incidence_gather_ref(u, v, w):
    return w[u] + w[v]
