"""Pallas TPU kernel: implicit incidence transpose-product (paper §5.1.2).

    g_e = w[u_e] + w[v_e]            (= (M^T w)_e, optionally * edge weight)

This is the gather-direction SpMV the paper credits with its largest
implicit-representation speedups (5.06x on bmatch): the incidence matrix
is never materialized — the edge list *is* the operator. On TPU, edge
index tiles stream through VMEM while the vertex vector w is resident
(blocked by vertex range for large graphs; the grid's second axis walks
vertex blocks, accumulating partial gathers — edges are pre-sorted by
endpoint block by `sparsela.partition`, so each edge tile touches one
block per endpoint).

This single-block variant holds w fully in VMEM; the dispatch layer
(`repro.kernels.dispatch.VMEM_VERTEX_LIMIT`, 3M f32 vertices — see the
headroom math there) falls back to the XLA path beyond that. The gather
runs in the input dtype end to end: f64 solves keep full precision
through the kernel path (interpret mode; real TPUs gate f64 to XLA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 8
TILE = SUBLANES * LANES


def _gather_kernel(E, u_ref, v_ref, w_ref, out_ref):
    i = pl.program_id(0)
    u = u_ref[...]
    v = v_ref[...]
    idx = jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 0) * LANES + jax.lax.broadcasted_iota(
        jnp.int32, (SUBLANES, LANES), 1
    )
    valid = (i * TILE + idx) < E
    u = jnp.where(valid, u, 0)
    v = jnp.where(valid, v, 0)
    w = w_ref[...]
    g = jnp.take(w, u.reshape(-1), axis=0) + jnp.take(w, v.reshape(-1), axis=0)
    out_ref[...] = jnp.where(valid, g.reshape(SUBLANES, LANES), jnp.zeros((), w.dtype))


def incidence_gather_pallas(u, v, w, interpret: bool = True):
    """g[e] = w[u[e]] + w[v[e]]; zero for padded edge slots."""
    E = u.shape[0]
    nt = max(1, (E + TILE - 1) // TILE)
    pad = nt * TILE - E
    up = jnp.pad(u, (0, pad)).reshape(nt * SUBLANES, LANES)
    vp = jnp.pad(v, (0, pad)).reshape(nt * SUBLANES, LANES)
    n = w.shape[0]
    n_pad = ((n + LANES - 1) // LANES) * LANES
    wp = jnp.pad(w, (0, n_pad - n))

    g = pl.pallas_call(
        functools.partial(_gather_kernel, E),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
            pl.BlockSpec((n_pad,), lambda i: (0,)),  # w resident in VMEM
        ],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt * SUBLANES, LANES), w.dtype),
        interpret=interpret,
    )(up, vp, wp)
    return g.reshape(-1)[:E]
