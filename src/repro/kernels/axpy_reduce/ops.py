"""Jitted dispatcher for the fused update (Alg. 2 lines 14-15 + cond)."""
from functools import partial

import jax

from .kernel import axpy_reduce_pallas
from .ref import axpy_reduce_ref


@partial(jax.jit, static_argnames=("impl",))
def axpy_reduce(y, dy, alpha, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        interpret = jax.default_backend() != "tpu"
        return axpy_reduce_pallas(y, dy, alpha, interpret=interpret)
    return axpy_reduce_ref(y, dy, alpha)
