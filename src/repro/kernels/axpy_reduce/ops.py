"""Dispatcher for the fused update (Alg. 2 lines 14-15 + cond).

Backend resolution happens host-side in the wrapper (not at trace time
inside the jit); see ``repro.kernels.dispatch``.
"""
from functools import partial

import jax

from ..dispatch import resolve_impl
from .kernel import axpy_reduce_pallas
from .ref import axpy_reduce_ref


@partial(jax.jit, static_argnames=("impl", "interpret"))
def _axpy_reduce_jit(y, dy, alpha, impl: str, interpret: bool):
    if impl == "pallas":
        return axpy_reduce_pallas(y, dy, alpha, interpret=interpret)
    return axpy_reduce_ref(y, dy, alpha)


def axpy_reduce(y, dy, alpha, impl: str = "auto"):
    """(y + alpha*dy, min, max) in one fused sweep."""
    impl, interpret = resolve_impl("axpy", impl, n=y.shape[0], dtype=y.dtype)
    return _axpy_reduce_jit(y, dy, alpha, impl, interpret)
