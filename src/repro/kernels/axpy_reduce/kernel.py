"""Pallas TPU kernel: fused constraint update + termination reductions.

    out = y + alpha * dy      and simultaneously  (min(out), max(out))

One HBM sweep covers Alg. 2 lines 14-15 plus the loop-condition
reductions (max packing / min covering values) that would otherwise be
three extra passes — the same fusion the paper implements with OpenMP
loop fusion (§5.1.3). Padded lanes contribute +inf/-inf neutrally.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
SUBLANES = 8
TILE = SUBLANES * LANES

_POS = 1e30
_NEG = -1e30


def _axpy_kernel(n, alpha_ref, y_ref, dy_ref, out_ref, red_ref, acc_ref):
    i = pl.program_id(0)
    nt = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[0] = jnp.float32(_POS)  # running min
        acc_ref[1] = jnp.float32(_NEG)  # running max

    out = y_ref[...].astype(jnp.float32) + alpha_ref[0] * dy_ref[...].astype(jnp.float32)
    idx = jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 0) * LANES + jax.lax.broadcasted_iota(
        jnp.int32, (SUBLANES, LANES), 1
    )
    valid = (i * TILE + idx) < n
    out_ref[...] = jnp.where(valid, out, 0.0)
    acc_ref[0] = jnp.minimum(acc_ref[0], jnp.min(jnp.where(valid, out, _POS)))
    acc_ref[1] = jnp.maximum(acc_ref[1], jnp.max(jnp.where(valid, out, _NEG)))

    @pl.when(i == nt - 1)
    def _fin():
        red_ref[0] = acc_ref[0]
        red_ref[1] = acc_ref[1]


def axpy_reduce_pallas(y, dy, alpha, interpret: bool = True):
    """Returns (y + alpha*dy, min, max) in one pass."""
    n = y.shape[0]
    nt = max(1, (n + TILE - 1) // TILE)
    pad = nt * TILE - n
    yp = jnp.pad(y.astype(jnp.float32), (0, pad)).reshape(nt * SUBLANES, LANES)
    dp = jnp.pad(dy.astype(jnp.float32), (0, pad)).reshape(nt * SUBLANES, LANES)
    a = alpha.astype(jnp.float32).reshape(1)
    out, red = pl.pallas_call(
        functools.partial(_axpy_kernel, n),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nt * SUBLANES, LANES), jnp.float32),
            jax.ShapeDtypeStruct((2,), jnp.float32),
        ],
        scratch_shapes=[pltpu.SMEM((2,), jnp.float32)],
        interpret=interpret,
    )(a, yp, dp)
    return out.reshape(-1)[:n], red[0], red[1]
