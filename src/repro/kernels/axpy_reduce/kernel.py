"""Pallas TPU kernel: fused constraint update + termination reductions.

    out = y + alpha * dy      and simultaneously  (min(out), max(out))

One HBM sweep covers Alg. 2 lines 14-15 plus the loop-condition
reductions (max packing / min covering values) that would otherwise be
three extra passes — the same fusion the paper implements with OpenMP
loop fusion (§5.1.3). ``core.mwu._iteration`` routes the x/y/z update
triple through this kernel when the dispatch layer selects pallas.
Padded lanes contribute +inf/-inf neutrally; arithmetic runs in the
input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
SUBLANES = 8
TILE = SUBLANES * LANES

_POS = 1e30
_NEG = -1e30


def _axpy_kernel(n, alpha_ref, y_ref, dy_ref, out_ref, red_ref, acc_ref):
    i = pl.program_id(0)
    nt = pl.num_programs(0)
    dt = acc_ref.dtype

    @pl.when(i == 0)
    def _init():
        acc_ref[0] = jnp.asarray(_POS, dt)  # running min
        acc_ref[1] = jnp.asarray(_NEG, dt)  # running max

    out = y_ref[...] + alpha_ref[0] * dy_ref[...]
    idx = jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 0) * LANES + jax.lax.broadcasted_iota(
        jnp.int32, (SUBLANES, LANES), 1
    )
    valid = (i * TILE + idx) < n
    out_ref[...] = jnp.where(valid, out, jnp.zeros((), dt))
    acc_ref[0] = jnp.minimum(acc_ref[0], jnp.min(jnp.where(valid, out, jnp.asarray(_POS, dt))))
    acc_ref[1] = jnp.maximum(acc_ref[1], jnp.max(jnp.where(valid, out, jnp.asarray(_NEG, dt))))

    @pl.when(i == nt - 1)
    def _fin():
        red_ref[0] = acc_ref[0]
        red_ref[1] = acc_ref[1]


def axpy_reduce_pallas(y, dy, alpha, interpret: bool = True):
    """Returns (y + alpha*dy, min, max) in one pass."""
    n = y.shape[0]
    dt = y.dtype
    nt = max(1, (n + TILE - 1) // TILE)
    pad = nt * TILE - n
    yp = jnp.pad(y, (0, pad)).reshape(nt * SUBLANES, LANES)
    dp = jnp.pad(dy.astype(dt), (0, pad)).reshape(nt * SUBLANES, LANES)
    a = alpha.astype(dt).reshape(1)
    out, red = pl.pallas_call(
        functools.partial(_axpy_kernel, n),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nt * SUBLANES, LANES), dt),
            jax.ShapeDtypeStruct((2,), dt),
        ],
        scratch_shapes=[pltpu.SMEM((2,), dt)],
        interpret=interpret,
    )(a, yp, dp)
    return out.reshape(-1)[:n], red[0], red[1]
