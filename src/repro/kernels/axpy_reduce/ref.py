"""Pure-jnp oracle for axpy_reduce (dtype-preserving)."""
import jax.numpy as jnp


def axpy_reduce_ref(y, dy, alpha):
    out = y + alpha * dy
    return out, jnp.min(out), jnp.max(out)
