"""Pure-jnp oracle for axpy_reduce."""
import jax.numpy as jnp


def axpy_reduce_ref(y, dy, alpha):
    out = y.astype(jnp.float32) + alpha.astype(jnp.float32) * dy.astype(jnp.float32)
    return out, jnp.min(out), jnp.max(out)
