"""Jitted GQA-aware wrapper for the flash attention kernel.

Accepts the model layout (B, S, H, dh) + GQA kv (B, S, Hkv, dh); folds
(B, Hkv, group) into the kernel's batch axis, pads sequences to block
multiples, and dispatches Pallas (TPU / interpret) or the XLA reference.
"""
from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import flash_attention_ref


def flash_attention(q, k, v, positions=None, *, causal=True, window=None,
                    block_q=512, block_k=512, impl="auto"):
    """q: (B,S,Hq,dh), k/v: (B,S,Hkv,dh) -> (B,S,Hq,dh).

    Backend resolution happens here, host-side, before the jit boundary:
    a ``jax.default_backend()`` read inside the jitted body would be
    frozen into the jit cache at first trace and served stale after a
    device switch (RPR001 — same contract as ``kernels.dispatch.resolve``).
    """
    platform = jax.default_backend()
    if impl == "auto":
        impl = "pallas" if platform == "tpu" else "xla"
    return _flash_attention_impl(
        q, k, v, positions, causal=causal, window=window,
        block_q=block_q, block_k=block_k, impl=impl,
        interpret=platform != "tpu",
    )


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "impl", "interpret"))
def _flash_attention_impl(q, k, v, positions=None, *, causal, window,
                          block_q, block_k, impl, interpret):
    B, S, Hq, dh = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv

    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(B * Hq, S, dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(B * Hq, S, dh)

    if impl == "pallas":
        bq = min(block_q, S)
        bk = min(block_k, S)
        pad_q = (bq - S % bq) % bq
        if pad_q:
            qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
            kf = jnp.pad(kf, ((0, 0), (0, pad_q), (0, 0)))
            vf = jnp.pad(vf, ((0, 0), (0, pad_q), (0, 0)))
        out = flash_attention_pallas(
            qf, kf, vf, causal=causal, window=window,
            block_q=bq, block_k=bk, interpret=interpret, true_seq_k=S,
        )[:, :S]
    else:
        out = flash_attention_ref(qf, kf, vf, causal=causal, window=window)
    return out.reshape(B, Hq, S, dh).transpose(0, 2, 1, 3)
