"""Jitted GQA-aware wrapper for the flash attention kernel.

Accepts the model layout (B, S, H, dh) + GQA kv (B, S, Hkv, dh); folds
(B, Hkv, group) into the kernel's batch axis, pads sequences to block
multiples, and dispatches Pallas (TPU / interpret) or the XLA reference.
"""
from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import flash_attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "impl"))
def flash_attention(q, k, v, positions=None, *, causal=True, window=None,
                    block_q=512, block_k=512, impl="auto"):
    """q: (B,S,Hq,dh), k/v: (B,S,Hkv,dh) -> (B,S,Hq,dh)."""
    B, S, Hq, dh = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"

    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(B * Hq, S, dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(B * Hq, S, dh)

    if impl == "pallas":
        interpret = jax.default_backend() != "tpu"
        bq = min(block_q, S)
        bk = min(block_k, S)
        pad_q = (bq - S % bq) % bq
        if pad_q:
            qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
            kf = jnp.pad(kf, ((0, 0), (0, pad_q), (0, 0)))
            vf = jnp.pad(vf, ((0, 0), (0, pad_q), (0, 0)))
        out = flash_attention_pallas(
            qf, kf, vf, causal=causal, window=window,
            block_q=bq, block_k=bk, interpret=interpret, true_seq_k=S,
        )[:, :S]
    else:
        out = flash_attention_ref(qf, kf, vf, causal=causal, window=window)
    return out.reshape(B, Hq, S, dh).transpose(0, 2, 1, 3)
