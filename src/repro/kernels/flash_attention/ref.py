"""Pure-jnp oracle for flash attention (folded-head layout)."""
import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """q: (BH, Sq, d); k/v: (BH, Sk, d)."""
    BH, Sq, d = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / np.sqrt(d)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    s = jnp.where(ok[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w.astype(v.dtype), v).astype(q.dtype)
