"""Pallas TPU kernel: flash attention (causal / sliding-window / GQA).

Grid: (batch*kv_heads*group, n_q_blocks, n_kv_blocks) with the kv axis
innermost (sequential): the kernel keeps a running (m, l, acc) in VMEM
scratch across kv steps — the classic IO-aware streaming softmax
(FlashAttention, arXiv:2205.14135), blocked for the MXU with
(block_q x d) @ (d x block_k) tiles.

Causal + sliding-window masking is positional: q/k tile coordinates are
derived from program ids, so fully-masked kv blocks past the diagonal
(or outside the window band) are SKIPPED via pl.when — the 2x triangle
saving dense XLA attention cannot express (DESIGN.md §4).

GQA is handled by the ops.py wrapper: q heads are folded into the batch
axis of the grid; the kv block index maps q-batch -> kv-head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _flash_kernel(scale, causal, window, block_q, block_k, seq_k,
                  q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # block-level skip: entirely above the diagonal or outside the window
    q_last = (qi + 1) * block_q - 1
    k_first = kj * block_k
    needed = True
    if causal:
        needed = k_first <= q_last
    if window is not None:
        q_first = qi * block_q
        k_last = (kj + 1) * block_k - 1
        needed = needed & (k_last > q_first - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)
        ok = k_pos < seq_k
        if causal:
            ok &= k_pos <= q_pos
        if window is not None:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, _NEG)

        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        c = jnp.exp(m_old - m_new)
        l_ref[...] = l_ref[...] * c + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * c[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _fin():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=None,
                           block_q=512, block_k=512, interpret=True,
                           true_seq_k=None):
    """q: (BH, Sq, d); k/v: (BH, Sk, d) — heads already folded into batch.

    Returns (BH, Sq, d). Sq/Sk padded to block multiples by the caller;
    ``true_seq_k`` masks the padded key tail.
    """
    BH, Sq, d = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = (Sq + block_q - 1) // block_q
    nk = (Sk + block_k - 1) // block_k
    scale = float(1.0 / np.sqrt(d))  # python float: no x64 promotion

    kern = functools.partial(
        _flash_kernel, scale, causal, window, block_q, block_k,
        Sk if true_seq_k is None else true_seq_k,
    )
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
