"""Pure-jnp oracle for the softmax_weights kernel (dtype-preserving)."""
import jax.numpy as jnp


def softmax_weights_ref(v, eta, sign: float = 1.0):
    a = (sign * eta) * v
    m = jnp.max(a)
    s = jnp.sum(jnp.exp(a - m))
    lse = m + jnp.log(s)
    return lse, jnp.exp(a - lse)
