"""Pallas TPU kernel: fused eta-softmax weights + smoothed max (paper §5.1.3).

Computes, in two HBM sweeps over a length-n vector v:

    lse  = logsumexp(sign * eta * v)         (pass 1: online max/sum)
    w    = exp(sign * eta * v - lse)         (pass 2: normalized weights)

which yields both smax_eta/smin_eta (= sign * lse / eta) and the MWU
weight vector grad smax/smin in one fused pipeline — the paper fuses
exactly this gradient computation on CPU with OpenMP + AVX-512; on TPU
the tile is an (8, 128)-aligned VMEM block and the reduction carry lives
in SMEM scratch across a sequential 1-D grid.

All arithmetic runs in the input dtype (f32 or, in interpret mode, f64 —
the dispatch gate keeps f64 off real TPUs), so kernel and XLA paths
agree to summation-order differences only.

Masked (padded) entries are handled by an explicit length argument:
lanes with global index >= n contribute -inf / 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
SUBLANES = 8
TILE = SUBLANES * LANES  # 1024 elements per VMEM tile

_NEG = -1e30


def _reduce_kernel(n, se_ref, v_ref, out_ref, acc_ref):
    """Pass 1: running (max m, sum s) over tiles; writes [m, lse] at the end."""
    i = pl.program_id(0)
    nt = pl.num_programs(0)
    dt = acc_ref.dtype

    @pl.when(i == 0)
    def _init():
        acc_ref[0] = jnp.asarray(_NEG, dt)  # running max
        acc_ref[1] = jnp.asarray(0.0, dt)  # running sum (scaled by exp(-m))

    a = v_ref[...] * se_ref[0]
    idx = jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 0) * LANES + jax.lax.broadcasted_iota(
        jnp.int32, (SUBLANES, LANES), 1
    )
    valid = (i * TILE + idx) < n
    a = jnp.where(valid, a, jnp.asarray(_NEG, dt))

    m_old = acc_ref[0]
    s_old = acc_ref[1]
    m_tile = jnp.max(a)
    m_new = jnp.maximum(m_old, m_tile)
    corr = jnp.exp(m_old - m_new)
    s_new = s_old * corr + jnp.sum(jnp.exp(a - m_new))
    acc_ref[0] = m_new
    acc_ref[1] = s_new

    @pl.when(i == nt - 1)
    def _fin():
        out_ref[0] = m_new
        out_ref[1] = m_new + jnp.log(s_new)  # lse


def _normalize_kernel(n, se_ref, v_ref, lse_ref, w_ref):
    """Pass 2: w = exp(sign*eta*v - lse), zero on padded lanes."""
    i = pl.program_id(0)
    a = v_ref[...] * se_ref[0]
    idx = jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 0) * LANES + jax.lax.broadcasted_iota(
        jnp.int32, (SUBLANES, LANES), 1
    )
    valid = (i * TILE + idx) < n
    w = jnp.exp(a - lse_ref[1])
    w_ref[...] = jnp.where(valid, w, jnp.zeros((), w.dtype)).astype(w_ref.dtype)


def softmax_weights_pallas(v, eta, sign: float = 1.0, interpret: bool = True):
    """Returns (lse, w) with lse = logsumexp(sign*eta*v), w = softmax(sign*eta*v)."""
    n = v.shape[0]
    dt = v.dtype
    nt = max(1, (n + TILE - 1) // TILE)
    vp = jnp.pad(v, (0, nt * TILE - n)).reshape(nt * SUBLANES, LANES)
    se = (jnp.asarray(sign, dt) * eta.astype(dt)).reshape(1)

    stats = pl.pallas_call(
        functools.partial(_reduce_kernel, n),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), dt),
        scratch_shapes=[pltpu.SMEM((2,), dt)],
        interpret=interpret,
    )(se, vp)

    w = pl.pallas_call(
        functools.partial(_normalize_kernel, n),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt * SUBLANES, LANES), dt),
        interpret=interpret,
    )(se, vp, stats)
    return stats[1], w.reshape(-1)[:n]
