"""Dispatcher for the fused softmax-weights kernel.

Backend resolution happens host-side in the wrapper (not at trace time
inside the jit) so a device switch re-resolves instead of serving a
stale cached choice; see ``repro.kernels.dispatch``.
"""
from __future__ import annotations

from functools import partial

import jax

from ..dispatch import resolve_impl
from .kernel import softmax_weights_pallas
from .ref import softmax_weights_ref


@partial(jax.jit, static_argnames=("sign", "impl", "interpret"))
def _softmax_weights_jit(v, eta, sign: float, impl: str, interpret: bool):
    if impl == "pallas":
        return softmax_weights_pallas(v, eta, sign=sign, interpret=interpret)
    return softmax_weights_ref(v, eta, sign=sign)


def softmax_weights(v, eta, sign: float = 1.0, impl: str = "auto"):
    """(lse, w): lse = logsumexp(sign*eta*v); w = softmax(sign*eta*v).

    smax_eta(v) = lse/eta (sign=+1); smin_eta(v) = -lse/eta (sign=-1).
    impl: "auto" (pallas on TPU, xla elsewhere) | "pallas" | "xla".
    """
    impl, interpret = resolve_impl("softmax", impl, n=v.shape[0], dtype=v.dtype)
    return _softmax_weights_jit(v, eta, sign, impl, interpret)
