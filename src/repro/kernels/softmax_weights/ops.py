"""Jitted dispatcher: Pallas on TPU, interpret-mode Pallas or pure-jnp on CPU."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import softmax_weights_pallas
from .ref import softmax_weights_ref


@partial(jax.jit, static_argnames=("sign", "impl"))
def softmax_weights(v, eta, sign: float = 1.0, impl: str = "auto"):
    """(lse, w): lse = logsumexp(sign*eta*v); w = softmax(sign*eta*v).

    smax_eta(v) = lse/eta (sign=+1); smin_eta(v) = -lse/eta (sign=-1).
    impl: "auto" (pallas on TPU, xla elsewhere) | "pallas" | "xla".
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        interpret = jax.default_backend() != "tpu"
        return softmax_weights_pallas(v, eta, sign=sign, interpret=interpret)
    return softmax_weights_ref(v, eta, sign=sign)
