"""Backend dispatch for the Pallas kernel pack (the MWU hot-path switch).

This module is the single place where "which implementation runs this
vector op" is decided. Three layers cooperate:

1. **Host-side resolution** — :func:`resolve` turns a backend *request*
   (``"auto" | "pallas" | "xla"``, from ``MWUOptions.kernel_backend`` or
   the ``REPRO_KERNEL_BACKEND`` env var) into a concrete, hashable
   :class:`KernelPolicy`. It reads ``jax.default_backend()`` and MUST be
   called outside ``jax.jit`` so a CPU→TPU device switch can never serve
   a stale cached choice: callers bake the resolved policy into their
   jit cache key as a static argument (``core.mwu.solve`` and
   ``repro.api.Solver.solve_batch`` both do).
2. **Trace-scoped policy** — :func:`use_policy` installs the resolved
   policy in a context variable for the duration of one solve trace;
   ``core.operators`` / ``core.smoothing`` / ``core.stepsize`` /
   ``core.mwu`` consult it via :func:`choose` at trace time. The default
   policy is pure XLA, so operators used outside a solve behave exactly
   as before.
3. **Per-op gate** — even under a ``pallas`` policy an individual call
   falls back to XLA when the kernel cannot serve it: gathers whose
   vertex vector exceeds :data:`VMEM_VERTEX_LIMIT`, float64 on a real
   TPU (no f64 VPU; interpret mode keeps f64 for CPU CI parity), or
   masked reductions (the mask-aware paths stay on XLA — handled at the
   call sites). Every decision is counted in :func:`stats` so tests and
   ``benchmarks/bench_breakdown.py`` can prove the pallas path is
   active rather than silently falling back.

The pallas entry points are wrapped in ``jax.custom_batching.custom_vmap``
with an XLA batch rule: ``Solver.solve_batch`` and the ``repro.lpserve``
lanes vmap the whole MWU ``lax.while_loop`` across bounds/instances, and
the batched lanes then run the (vmap-composable, still fused-by-XLA)
reference path while unbatched solves keep the Mosaic kernels.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
import os
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .axpy_reduce.kernel import axpy_reduce_pallas
from .axpy_reduce.ref import axpy_reduce_ref
from .incidence_gather.kernel import incidence_gather_pallas
from .incidence_gather.ref import incidence_gather_ref
from .linesearch_probe.kernel import linesearch_probe_pallas
from .linesearch_probe.ref import linesearch_probe_ref
from .softmax_weights.kernel import softmax_weights_pallas
from .softmax_weights.ref import softmax_weights_ref

__all__ = [
    "KernelPolicy",
    "XLA_POLICY",
    "BACKENDS",
    "ENV_VAR",
    "VMEM_VERTEX_LIMIT",
    "VMEM_BYTES_PER_CORE",
    "VMEM_HEADROOM_BYTES",
    "vmem_budget_bytes",
    "vmem_vertex_limit",
    "resolve",
    "resolve_impl",
    "use_policy",
    "active_policy",
    "choose",
    "stats",
    "reset_stats",
    "gather_pallas",
    "softmax_pallas",
    "probe_pallas",
    "axpy_pallas",
]

ENV_VAR = "REPRO_KERNEL_BACKEND"
BACKENDS = ("auto", "pallas", "xla")

# Single-block gather keeps the whole vertex vector w resident in VMEM.
# A TPU core has ~16 MiB of VMEM; at 3M f32 vertices w occupies 12 MiB,
# leaving >= 4 MiB for the double-buffered (8, 128) edge-index and output
# tiles the grid streams. (4M vertices — the figure an old kernel.py
# docstring quoted — would fill VMEM exactly and leave no tile headroom.)
VMEM_VERTEX_LIMIT = 3_000_000

# The budget the limits above are derived from, shared with
# repro.tracecheck's vmem-footprint rule so the static linter and the
# runtime gate can never disagree about what "fits": a TPU core's VMEM
# minus headroom for Mosaic scratch/semaphores and scalar prefetch.
VMEM_BYTES_PER_CORE = 16 * 2**20
VMEM_HEADROOM_BYTES = 2**20


def vmem_budget_bytes() -> int:
    """Max estimated block footprint a dispatched kernel may occupy."""
    return VMEM_BYTES_PER_CORE - VMEM_HEADROOM_BYTES


def vmem_vertex_limit(dtype) -> int:
    """Vertex cap for the VMEM-resident gather, scaled by element size."""
    return VMEM_VERTEX_LIMIT * 4 // jnp.dtype(dtype).itemsize


@dataclass(frozen=True)
class KernelPolicy:
    """A concrete, hashable backend choice (usable as a jit static arg).

    ``backend`` is ``"pallas"`` or ``"xla"`` — never ``"auto"``; the
    resolution happened in :func:`resolve`. ``interpret`` runs the
    pallas kernels through the Pallas interpreter (pure XLA lowering),
    which is how CPU CI exercises the exact kernel code path.
    """

    backend: str = "xla"
    interpret: bool = False


XLA_POLICY = KernelPolicy("xla", False)


def resolve(request: str | None = "auto") -> KernelPolicy:
    """Resolve a backend request into a concrete :class:`KernelPolicy`.

    Precedence: an explicit ``"pallas"`` / ``"xla"`` request wins; for
    ``"auto"`` (or ``None``) the ``REPRO_KERNEL_BACKEND`` env var is
    consulted, then the platform heuristic (pallas on TPU, xla
    elsewhere). Call this OUTSIDE ``jax.jit`` and pass the result
    through as a static argument — ``jax.default_backend()`` read
    inside a traced function is frozen into the jit cache and goes
    stale when the device set changes.
    """
    req = request or "auto"
    if req == "auto":
        req = os.environ.get(ENV_VAR, "") or "auto"
    if req not in BACKENDS:
        raise ValueError(f"kernel backend must be one of {BACKENDS}, got {req!r}")
    platform = jax.default_backend()
    if req == "auto":
        req = "pallas" if platform == "tpu" else "xla"
    if req == "xla":
        return XLA_POLICY
    return KernelPolicy("pallas", interpret=platform != "tpu")


_ACTIVE: contextvars.ContextVar[KernelPolicy] = contextvars.ContextVar(
    "repro_kernel_policy", default=XLA_POLICY
)


@contextlib.contextmanager
def use_policy(policy: KernelPolicy):
    """Install ``policy`` for the enclosed (trace-time) region."""
    token = _ACTIVE.set(policy)
    try:
        yield policy
    finally:
        _ACTIVE.reset(token)


def active_policy() -> KernelPolicy:
    return _ACTIVE.get()


# -- dispatch accounting ---------------------------------------------------
# Counts trace-time decisions per op; benchmarks and tests use this to
# assert the pallas path is genuinely active (not silently falling back).
_STATS: dict[str, dict[str, int]] = {}


def _note(op: str, impl: str) -> None:
    d = _STATS.setdefault(op, {"pallas": 0, "xla": 0})
    d[impl] += 1


def reset_stats() -> None:
    _STATS.clear()


def stats() -> dict[str, dict[str, int]]:
    return {op: dict(d) for op, d in _STATS.items()}


def _gate(op: str, policy: KernelPolicy, n: int, dtype) -> str:
    """Per-op feasibility of the pallas path, from static shape/dtype."""
    if policy.backend != "pallas":
        return "xla"
    dt = jnp.dtype(dtype)
    if jnp.issubdtype(dt, jnp.floating) and dt.itemsize >= 8 and not policy.interpret:
        return "xla"  # real TPUs have no f64 vector unit
    if op == "gather" and n > vmem_vertex_limit(dtype):
        return "xla"  # w no longer fits VMEM single-block
    return "pallas"


def choose(op: str, x) -> str:
    """Trace-time per-op decision under the active policy (records stats).

    ``x`` is the vector whose length/dtype gates the kernel: the vertex
    vector for ``"gather"``, the reduced vector for ``"softmax"`` /
    ``"probe"`` / ``"axpy"``.
    """
    impl = _gate(op, active_policy(), x.shape[0], x.dtype)
    _note(op, impl)
    return impl


def resolve_impl(op: str, impl: str, *, n: int, dtype) -> tuple[str, bool]:
    """Host-side resolution for the standalone ``ops.py`` dispatchers.

    Returns ``(impl, interpret)`` with ``impl`` concrete. An explicit
    ``"pallas"``/``"xla"`` request is honored as-is (tests force the
    kernel path regardless of platform); only ``"auto"`` consults the
    env var, platform, and the per-op gate. Lives outside the jitted
    inner functions so repeated calls re-read the platform.
    """
    interpret = jax.default_backend() != "tpu"
    if impl == "auto":
        impl = _gate(op, resolve("auto"), n, dtype)
    return impl, interpret


# -- vmap-composable pallas entry points -----------------------------------
def _bcast(x, batched: bool, axis_size: int):
    return x if batched else jax.lax.broadcast(x, (axis_size,))


@functools.lru_cache(maxsize=None)
def _gather_fn(interpret: bool):
    @jax.custom_batching.custom_vmap
    def gather(u, v, w):
        return incidence_gather_pallas(u, v, w, interpret=interpret)

    @gather.def_vmap
    def _rule(axis_size, in_batched, u, v, w):  # noqa: ARG001
        # Batched lanes (solve_batch / lpserve) take the XLA gather —
        # vmap-composable and still one fused HLO per lane.
        u, v, w = (
            _bcast(a, b, axis_size) for a, b in zip((u, v, w), in_batched)
        )
        return jax.vmap(incidence_gather_ref)(u, v, w), True

    return gather


@functools.lru_cache(maxsize=None)
def _softmax_fn(sign: float, interpret: bool):
    @jax.custom_batching.custom_vmap
    def softmax(v, eta):
        return softmax_weights_pallas(v, eta, sign=sign, interpret=interpret)

    @softmax.def_vmap
    def _rule(axis_size, in_batched, v, eta):  # noqa: ARG001
        v, eta = (_bcast(a, b, axis_size) for a, b in zip((v, eta), in_batched))
        lse, w = jax.vmap(lambda vv, ee: softmax_weights_ref(vv, ee, sign))(v, eta)
        return (lse, w), (True, True)

    return softmax


@functools.lru_cache(maxsize=None)
def _probe_fn(sign: float, interpret: bool):
    @jax.custom_batching.custom_vmap
    def probe(y, dy, alpha, eta):
        return linesearch_probe_pallas(y, dy, alpha, eta, sign=sign, interpret=interpret)

    @probe.def_vmap
    def _rule(axis_size, in_batched, y, dy, alpha, eta):  # noqa: ARG001
        y, dy, alpha, eta = (
            _bcast(a, b, axis_size) for a, b in zip((y, dy, alpha, eta), in_batched)
        )
        out = jax.vmap(lambda *a: linesearch_probe_ref(*a, sign))(y, dy, alpha, eta)
        return out, (True, True, True)

    return probe


@functools.lru_cache(maxsize=None)
def _axpy_fn(interpret: bool):
    @jax.custom_batching.custom_vmap
    def axpy(y, dy, alpha):
        return axpy_reduce_pallas(y, dy, alpha, interpret=interpret)

    @axpy.def_vmap
    def _rule(axis_size, in_batched, y, dy, alpha):  # noqa: ARG001
        y, dy, alpha = (
            _bcast(a, b, axis_size) for a, b in zip((y, dy, alpha), in_batched)
        )
        out = jax.vmap(axpy_reduce_ref)(y, dy, alpha)
        return out, (True, True, True)

    return axpy


def gather_pallas(u, v, w):
    """``g_e = w[u_e] + w[v_e]`` through the Pallas kernel (vmap-safe)."""
    return _gather_fn(active_policy().interpret)(u, v, w)


def softmax_pallas(v, eta, sign: float = 1.0):
    """``(lse, softmax(sign*eta*v))`` through the fused kernel (vmap-safe)."""
    return _softmax_fn(float(sign), active_policy().interpret)(v, jnp.asarray(eta, v.dtype))


def probe_pallas(y, dy, alpha, eta, sign: float = 1.0):
    """One fused line-search probe sweep: ``(lse, slope, min_v)`` (vmap-safe)."""
    return _probe_fn(float(sign), active_policy().interpret)(
        y, dy, jnp.asarray(alpha, y.dtype), jnp.asarray(eta, y.dtype)
    )


def axpy_pallas(y, dy, alpha):
    """``(y + alpha*dy, min, max)`` in one fused sweep (vmap-safe)."""
    return _axpy_fn(active_policy().interpret)(y, dy, jnp.asarray(alpha, y.dtype))
