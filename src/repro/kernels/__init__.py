"""Pallas TPU kernels for the paper's compute hot spots (DESIGN.md §4).

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jitted dispatcher: Mosaic on TPU / interpret or XLA elsewhere)
and ref.py (pure-jnp oracle used by tests/test_kernels.py sweeps):

  softmax_weights  — eta-softmax weights + smoothed max (MWU gradients)
  incidence_gather — g_e = w[u_e] + w[v_e]  (implicit M^T w, §5.1.2)
  axpy_reduce      — fused x+alpha*d with min/max reductions (Alg.2 l.14-15)
  linesearch_probe — fused Phi/Psi/derivative probe (Alg. 3 inner loop)
  flash_attention  — causal/SWA/GQA streaming attention (plane B prefill)

:mod:`repro.kernels.dispatch` is the backend-selection layer that routes
the MWU iteration itself (``core.operators`` / ``core.smoothing`` /
``core.stepsize`` / ``core.mwu``) through these kernels: a host-side
``resolve()`` turns a ``"auto" | "pallas" | "xla"`` request into a frozen
:class:`~repro.kernels.dispatch.KernelPolicy` (baked into jit cache
keys), ``use_policy()`` scopes it over a trace, and per-op gates fall
back to the legacy jnp expressions for masked reductions, f64 on real
TPUs, and gathers past the VMEM vertex limit.  Batched callers keep
working because each kernel wrapper is a ``jax.custom_batching.custom_vmap``
whose batch rule vmaps the jnp oracle.
"""
