"""Pallas TPU kernels for the paper's compute hot spots (DESIGN.md §4).

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jitted dispatcher: Mosaic on TPU / interpret or XLA elsewhere)
and ref.py (pure-jnp oracle used by tests/test_kernels.py sweeps):

  softmax_weights  — eta-softmax weights + smoothed max (MWU gradients)
  incidence_gather — g_e = w[u_e] + w[v_e]  (implicit M^T w, §5.1.2)
  axpy_reduce      — fused x+alpha*d with min/max reductions (Alg.2 l.14-15)
  linesearch_probe — fused Phi/Psi/derivative probe (Alg. 3 inner loop)
  flash_attention  — causal/SWA/GQA streaming attention (plane B prefill)
"""
