"""One driver for every graph LP: bound search with vmap-batched feasibility.

``Solver`` turns a declarative :class:`~repro.api.problem.Problem` into a
:class:`Solution` by reducing optimization to feasibility (paper §2.2)
and searching the objective bound. Two execution modes:

* ``batch_width == 1`` — the paper's sequential geometric binary search,
  one jitted feasibility solve per probe (exactly the legacy
  ``core.feasibility`` drivers).
* ``batch_width K > 1`` — speculative bracket evaluation (DESIGN.md §5
  note): each round instantiates K candidate bounds and ``jax.vmap``s
  the MWU ``lax.while_loop`` across them in ONE XLA call, shrinking the
  bracket by ~(K+1)x per round instead of 2x. The parallel-LP analogue
  of Allen-Zhu & Orecchia / Wang et al.'s width-parallelism.

``solve_batch`` exposes the raw fan-out: batched ``MWUResult`` across an
array of bounds, optionally also across stacked same-shape graph
instances (``stack_problems``).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core import mwu as _mwu
from ..core.mwu import MWUOptions, MWUResult, Status, _run, solve, solve_traced
from ..kernels import dispatch as _kd
from .problem import Problem

__all__ = [
    "Solution",
    "Solver",
    "stack_problems",
    "feasibility_solution",
    "not_found_solution",
    "certify_solution",
]


@dataclass
class Solution:
    """Unified result of a ``Solver`` run.

    ``objective`` is the certified value of ``x`` after the (1+eps)
    rescale (max: divide by packing overshoot; min: exploit covering
    slack); for densest-subgraph it is the certified density bound.
    ``trace`` (optional) is a list of per-feasibility-call dicts from the
    io_callback trace hook, each with the probed ``bound`` plus the
    ``max_violation`` / ``alpha`` / ``probes`` arrays of Figure 3.
    """

    problem: str
    status: int  # core Status code of the certifying solve
    x: np.ndarray | None  # best feasible solution (original variables)
    objective: float
    bound: float  # final binary-search bound
    max_px: float  # certificates at exit
    min_cx: float
    feasibility_calls: int
    mwu_iters_total: int
    ls_probes_total: int
    last_result: MWUResult | None = None
    trace: list | None = None

    @property
    def found(self) -> bool:
        return self.x is not None

    @property
    def feasible(self) -> bool:
        return self.status == Status.FEASIBLE and self.found


@partial(jax.jit, static_argnames=("opts", "problem_axis", "kernels"))
def _feasibility_batch(problem: Problem, bounds, opts: MWUOptions, problem_axis, kernels=None):
    """vmap the MWU while_loop across bounds (and optionally instances).

    ``kernels`` is the host-resolved KernelPolicy (static): pallas entry
    points are ``custom_vmap``-wrapped, so batched lanes transparently
    take the vmap-composable XLA rule while the policy still keys the
    jit cache consistently with the unbatched path.
    """

    def one(prob, b):
        P, C, pm, cm = prob.instantiate(b)
        return _run(P, C, opts, pm, cm, kernels=kernels)

    return jax.vmap(one, in_axes=(problem_axis, 0))(problem, bounds)


def _check_stackable(problems: list[Problem]) -> None:
    """Raise a ValueError naming the first mismatched aux field / leaf."""
    ref = problems[0]
    ref_flat, ref_tree = jax.tree_util.tree_flatten_with_path(ref)
    for i, p in enumerate(problems[1:], start=1):
        if isinstance(ref, Problem) and isinstance(p, Problem):
            for f in ("name", "kind", "sense", "bound_mode", "n_vars", "nnz", "make_ops"):
                a, b = getattr(ref, f), getattr(p, f)
                if a != b:
                    raise ValueError(
                        f"stack_problems: problem 0 and problem {i} differ in "
                        f"static field {f!r}: {a!r} vs {b!r}; only problems of "
                        "the same family can be instance-batched"
                    )
        flat, tree = jax.tree_util.tree_flatten_with_path(p)
        if tree != ref_tree:
            keys0 = {jax.tree_util.keystr(k) for k, _ in ref_flat}
            keys = {jax.tree_util.keystr(k) for k, _ in flat}
            diff = sorted(keys0.symmetric_difference(keys)) or ["<nested structure>"]
            raise ValueError(
                f"stack_problems: problem 0 and problem {i} have different "
                f"pytree structure (mismatched leaves: {', '.join(diff)}); "
                "pad differently-shaped problems into a common bucket first "
                "(repro.lpserve.pad_problems)"
            )
        for (key, leaf0), (_, leaf) in zip(ref_flat, flat):
            s0, s = jnp.shape(leaf0), jnp.shape(leaf)
            if s0 != s:
                raise ValueError(
                    f"stack_problems: leaf {jax.tree_util.keystr(key)!r} has "
                    f"shape {s} in problem {i} but {s0} in problem 0; pad "
                    "differently-sized graphs into a common shape bucket "
                    "first (repro.lpserve.pad_problems)"
                )


def stack_problems(problems: list[Problem]) -> Problem:
    """Tree-stack same-shape Problems for instance-batched ``solve_batch``.

    All problems must share pytree structure and leaf shapes (same
    vertex/edge counts — pad into a shape bucket with
    :func:`repro.lpserve.pad_problems` when they differ). Mismatches
    raise a ``ValueError`` naming the offending field or leaf.
    """
    if not problems:
        raise ValueError("stack_problems: need at least one problem")
    _check_stackable(list(problems))
    return jax.tree.map(lambda *ls: jnp.stack([jnp.asarray(l) for l in ls]), *problems)


class Solver:
    """The public facade: Problem in, Solution out.

    Parameters
    ----------
    opts:        core MWU configuration (eps, step rule, iteration cap).
    batch_width: feasibility probes evaluated per search round in one
                 vmapped XLA call; 1 reproduces the paper's sequential
                 binary search.
    rel_tol:     bound-search granularity (default eps/2, so the search
                 does not compound the solver's eps past the paper's
                 acceptance band).
    max_calls:   total feasibility-solve budget per ``solve``.
    """

    def __init__(
        self,
        opts: MWUOptions | None = None,
        *,
        batch_width: int = 4,
        rel_tol: float | None = None,
        max_calls: int = 64,
    ):
        self.opts = opts if opts is not None else MWUOptions()
        if batch_width < 1:
            raise ValueError("batch_width must be >= 1")
        self.batch_width = int(batch_width)
        self.rel_tol = rel_tol
        self.max_calls = int(max_calls)

    # -- feasibility primitives ---------------------------------------
    def feasible(self, problem: Problem, bound=None, trace: bool = False):
        """One feasibility solve at a concrete bound.

        Returns ``MWUResult`` (or ``(MWUResult, trace_dict)`` with
        ``trace=True``). Instantiates the operators host-side so the
        core jit cache is keyed on operator structure, not on the bound.
        """
        P, C, pm, cm = problem.instantiate(bound)
        if trace:
            return solve_traced(P, C, self.opts, p_mask=pm, c_mask=cm)
        return solve(P, C, self.opts, p_mask=pm, c_mask=cm)

    def solve_batch(self, problem: Problem, bounds, *, batched_problem: bool = False) -> MWUResult:
        """Batched feasibility: vmap the MWU loop across ``bounds``.

        One XLA call evaluates every bound concurrently (speculative
        bracket evaluation). With ``batched_problem=True``, ``problem``
        must carry a leading batch axis on every leaf (see
        :func:`stack_problems`) matching ``bounds`` — fan-out across
        independent graph instances.

        Returns an ``MWUResult`` whose every field has leading dim
        ``len(bounds)``.
        """
        bounds = jnp.atleast_1d(jnp.asarray(bounds))
        kernels = _kd.resolve(self.opts.kernel_backend)  # host-side, pre-jit
        return _feasibility_batch(
            problem, bounds, self.opts, 0 if batched_problem else None, kernels=kernels
        )

    # -- AOT inspection hooks (repro.tracecheck) -----------------------
    # Same jit entries / statics / host-side resolution as the executing
    # paths above, so the linted program is the program a call would run.
    def lower_feasible(self, problem: Problem, bound=None, *, trace: bool = False):
        """AOT-lower one :meth:`feasible` call (``jax.stages.Lowered``)."""
        P, C, pm, cm = problem.instantiate(bound)
        return _mwu.lower(P, C, self.opts, p_mask=pm, c_mask=cm, trace=trace)

    def jaxpr_feasible(self, problem: Problem, bound=None, *, trace: bool = False):
        """ClosedJaxpr of one :meth:`feasible` call (primitive-level view)."""
        P, C, pm, cm = problem.instantiate(bound)
        return _mwu.solve_jaxpr(P, C, self.opts, p_mask=pm, c_mask=cm, trace=trace)

    def lower_batch(self, problem: Problem, bounds, *, batched_problem: bool = False):
        """AOT-lower one :meth:`solve_batch` call without executing it."""
        bounds = jnp.atleast_1d(jnp.asarray(bounds))
        kernels = _kd.resolve(self.opts.kernel_backend)
        return _feasibility_batch.lower(
            problem, bounds, self.opts, 0 if batched_problem else None, kernels=kernels
        )

    def jaxpr_batch(self, problem: Problem, bounds, *, batched_problem: bool = False):
        """ClosedJaxpr of one :meth:`solve_batch` call."""
        bounds = jnp.atleast_1d(jnp.asarray(bounds))
        kernels = _kd.resolve(self.opts.kernel_backend)
        axis = 0 if batched_problem else None
        fn = _feasibility_batch.__wrapped__

        def call(p, b):
            return fn(p, b, self.opts, axis, kernels=kernels)

        return jax.make_jaxpr(call)(problem, bounds)

    # -- the unified optimization driver ------------------------------
    def solve(self, problem: Problem, *, trace: bool = False) -> Solution:
        """Optimize ``problem`` via bound search over feasibility calls."""
        if problem.bound_mode == "none":
            return self._solve_feasibility(problem, trace)
        return self._bound_search(problem, trace)

    # pure feasibility problems skip the search entirely
    def _solve_feasibility(self, problem: Problem, trace: bool) -> Solution:
        traces = None
        if trace:
            res, tr = self.feasible(problem, trace=True)
            traces = [dict(bound=float("nan"), **tr)]
        else:
            res = self.feasible(problem)
        stats = {"calls": 1, "iters": int(res.iters), "probes": int(res.ls_probes)}
        return feasibility_solution(problem, res, stats, traces)

    def _probe(self, problem, bounds, trace, traces, stats):
        """Evaluate feasibility at each bound; batched when width allows."""
        outs = []
        if len(bounds) > 1 and not trace:
            batch = self.solve_batch(problem, jnp.asarray(bounds))
            status = np.asarray(batch.status)
            for j, b in enumerate(bounds):
                lane = jax.tree.map(lambda a: a[j], batch)
                outs.append((int(status[j]) == Status.FEASIBLE, lane))
        else:
            for b in bounds:
                if trace:
                    res, tr = self.feasible(problem, b, trace=True)
                    traces.append(dict(bound=float(b), **tr))
                else:
                    res = self.feasible(problem, b)
                outs.append((int(res.status) == Status.FEASIBLE, res))
        stats["calls"] += len(bounds)
        stats["iters"] += sum(int(r.iters) for _, r in outs)
        stats["probes"] += sum(int(r.ls_probes) for _, r in outs)
        return outs

    def _bound_search(self, problem: Problem, trace: bool) -> Solution:
        is_max = problem.feasible_side == "lo"
        lo, hi = float(problem.lo), float(problem.hi)
        rel = self.rel_tol if self.rel_tol is not None else self.opts.eps / 2
        K = 1 if trace else self.batch_width
        stats = {"calls": 0, "iters": 0, "probes": 0}
        traces: list = [] if trace else None
        best = best_bound = None

        # min-like senses: the feasible side is hi; the legacy drivers
        # check it up front and bail immediately when even hi fails.
        # (With K > 1 the endpoint could ride along in round 1's batch,
        # but checking it alone first keeps the not-found exit cheap.)
        if not is_max:
            (ok, res), = self._probe(problem, [hi], trace, traces, stats)
            if not ok:
                return self._not_found(problem, hi, res, stats, traces)
            best, best_bound = res, hi

        first = True
        while hi / max(lo, 1e-300) > 1.0 + rel and stats["calls"] < self.max_calls:
            r = hi / max(lo, 1e-300)
            if first and is_max and K > 1:
                # fold the feasible-side endpoint lo into round 1's batch
                pts = [lo * r ** (k / K) for k in range(K)]
            else:
                pts = [lo * r ** (k / (K + 1)) for k in range(1, K + 1)]
            outs = self._probe(problem, pts, trace, traces, stats)
            feas = [ok for ok, _ in outs]
            if is_max:
                # feasible for small bounds: push lo up to the largest
                # feasible probe, pull hi down to the smallest infeasible.
                f_idx = [i for i, ok in enumerate(feas) if ok]
                if f_idx:
                    j = f_idx[-1]
                    lo, best, best_bound = pts[j], outs[j][1], pts[j]
                else:
                    if first and K > 1:  # round 1 included lo itself
                        return self._not_found(problem, lo, outs[0][1], stats, traces)
                i_idx = [i for i, ok in enumerate(feas) if not ok]
                if i_idx:
                    hi = pts[i_idx[0]]
            else:
                # feasible for large bounds: mirror image
                f_idx = [i for i, ok in enumerate(feas) if ok]
                if f_idx:
                    j = f_idx[0]
                    hi, best, best_bound = pts[j], outs[j][1], pts[j]
                i_idx = [i for i, ok in enumerate(feas) if not ok]
                if i_idx:
                    lo = pts[i_idx[-1]]
            first = False

        if best is None:  # only reachable for sense="max" (lo never probed)
            (ok, res), = self._probe(problem, [lo], trace, traces, stats)
            if not ok:
                return self._not_found(problem, lo, res, stats, traces)
            best, best_bound = res, lo

        return self._certify(problem, best, best_bound, stats, traces)

    def _not_found(self, problem, bound, res, stats, traces) -> Solution:
        return not_found_solution(problem, bound, res, stats, traces)

    def _certify(self, problem, best, best_bound, stats, traces) -> Solution:
        return certify_solution(problem, best, best_bound, stats, traces)


# -- Solution construction (shared with repro.lpserve's engine) -----------
def feasibility_solution(problem, res, stats, traces=None) -> Solution:
    """Solution for a single feasibility solve (``bound_mode="none"``)."""
    ok = int(res.status) == Status.FEASIBLE
    return Solution(
        problem=problem.name,
        status=int(res.status),
        x=np.asarray(res.x) if ok else None,
        objective=float("nan"),
        bound=float("nan"),
        max_px=float(res.max_px),
        min_cx=float(res.min_cx),
        feasibility_calls=stats["calls"],
        mwu_iters_total=stats["iters"],
        ls_probes_total=stats["probes"],
        last_result=res,
        trace=traces,
    )


def not_found_solution(problem, bound, res, stats, traces=None) -> Solution:
    """Solution reporting that even the easy endpoint bound was infeasible."""
    return Solution(
        problem=problem.name,
        status=int(res.status),
        x=None,
        objective=0.0,
        bound=float(bound),
        max_px=float(res.max_px),
        min_cx=float(res.min_cx),
        feasibility_calls=stats["calls"],
        mwu_iters_total=stats["iters"],
        ls_probes_total=stats["probes"],
        last_result=res,
        trace=traces,
    )


def certify_solution(problem, best, best_bound, stats, traces=None) -> Solution:
    """Rescale the raw MWU point into a certified solution (§2.2)."""
    x = np.asarray(best.x)
    if problem.sense == "max":
        # Px <= 1+eps: dividing by the overshoot certifies Px <= 1
        # at an objective loss of at most (1+eps).
        x = x / max(float(best.max_px), 1.0)
        objective = float(np.dot(np.asarray(problem.c), x))
    elif problem.bound_mode == "objective_packing":
        # covering slack is free objective: x/min(Cx) stays feasible
        x = x / max(float(best.min_cx), 1.0)
        objective = float(np.dot(np.asarray(problem.c), x))
    else:
        # densest-style: the bound itself is the certified objective
        objective = float(best_bound)
    return Solution(
        problem=problem.name,
        status=int(best.status),
        x=x,
        objective=objective,
        bound=float(best_bound),
        max_px=float(best.max_px),
        min_cx=float(best.min_cx),
        feasibility_calls=stats["calls"],
        mwu_iters_total=stats["iters"],
        ls_probes_total=stats["probes"],
        last_result=best,
        trace=traces,
    )
