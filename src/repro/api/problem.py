"""Declarative problem specs for the unified solver facade.

A :class:`Problem` is a pytree-registered description of one graph LP:
the implicit operators (P packing rows, C covering rows), an optional
linear objective, optional row masks, binary-search bounds, and static
metadata (sense, kind, how the search bound enters the feasibility LP).

Because a Problem is a pytree whose search bound enters through array
leaves (``OnesRow.inv_bound`` / ``ScaledRows.scale``), feasibility calls
can be ``jax.vmap``-ed across bounds and across same-shape graph
instances — the batched execution the DESIGN.md §5 note anticipates.

``bound_mode`` declares how a candidate bound M builds the feasibility
LP ``exists x >= 0 : P x <= 1, C x >= 1`` (paper §2.2, §3):

* ``objective_covering`` — max <c,x> : covering row <c,x>/M >= 1 (packing LPs)
* ``objective_packing``  — min <c,x> : packing  row <c,x>/M <= 1 (covering LPs)
* ``scale_packing``      — scale every packing row by 1/M (densest subgraph's
                           density bound D, eq. 15)
* ``callable``           — escape hatch: ``make_ops(M) -> (P, C)`` (legacy
                           ``densest_subgraph_search`` shim)
* ``none``               — pure feasibility, no bound search
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..core.operators import LinOp, OnesRow, ScaledRows

__all__ = ["Problem", "SENSES", "BOUND_MODES"]

SENSES = ("max", "min", "feasibility")
BOUND_MODES = ("objective_covering", "objective_packing", "scale_packing", "callable", "none")

# pytree split: leaves may be traced / batched, aux must be hashable.
_LEAF_FIELDS = ("P", "C", "c", "p_mask", "c_mask", "lo", "hi")
_AUX_FIELDS = ("name", "kind", "sense", "bound_mode", "n_vars", "nnz", "make_ops")


@dataclass
class Problem:
    """One graph LP, declaratively.

    ``graph`` is host-side metadata only and is dropped by pytree
    flattening (a ``Graph`` holds numpy arrays, which would poison jit
    cache keys); everything the solver needs lives in the other fields.
    """

    name: str
    kind: str  # "packing" | "covering" | "densest" | "mixed"
    sense: str  # see SENSES
    bound_mode: str  # see BOUND_MODES
    P: LinOp | None = None
    C: LinOp | None = None
    c: Any = None  # optional (n,) nonnegative objective
    p_mask: Any = None  # optional (m_p,) bool
    c_mask: Any = None  # optional (m_c,) bool
    lo: Any = 1.0  # binary-search bracket (feasible side depends on sense)
    hi: Any = 1.0
    n_vars: int = 0
    nnz: int = 0
    make_ops: Callable | None = None  # bound_mode="callable" only
    graph: Any = None  # metadata; excluded from the pytree

    def __post_init__(self):
        if self.sense not in SENSES:
            raise ValueError(f"sense must be one of {SENSES}, got {self.sense!r}")
        if self.bound_mode not in BOUND_MODES:
            raise ValueError(f"bound_mode must be one of {BOUND_MODES}, got {self.bound_mode!r}")

    # -- feasibility instantiation ------------------------------------
    def instantiate(self, bound=None):
        """Build (P, C, p_mask, c_mask) for one candidate bound.

        ``bound`` may be a python float (host-side sequential path) or a
        traced scalar (under ``jax.vmap`` across bounds). The returned
        operators feed straight into the core MWU driver.
        """
        if self.bound_mode == "none":
            return self.P, self.C, self.p_mask, self.c_mask
        if bound is None:
            raise ValueError(f"problem {self.name!r} needs a bound (mode {self.bound_mode!r})")
        if self.bound_mode == "callable":
            P, C = self.make_ops(bound)
            return P, C, self.p_mask, self.c_mask
        b = jnp.asarray(bound)
        if self.bound_mode == "objective_covering":
            C = OnesRow(c=self.c, inv_bound=(1.0 / b).astype(self.c.dtype))
            return self.P, C, self.p_mask, None
        if self.bound_mode == "objective_packing":
            P = OnesRow(c=self.c, inv_bound=(1.0 / b).astype(self.c.dtype))
            return P, self.C, None, self.c_mask
        # scale_packing: divide every packing row by the bound
        scale = jnp.ones((self.P.shape[0],), b.dtype) / b
        return ScaledRows(scale=scale, inner=self.P), self.C, self.p_mask, self.c_mask

    @property
    def feasible_side(self) -> str:
        """Which end of [lo, hi] the feasibility predicate prefers.

        "max" problems are feasible for small bounds (any achievable
        objective), "min"/densest problems for large ones.
        """
        return "lo" if self.sense == "max" else "hi"

    # -- convenience --------------------------------------------------
    def solve(self, opts=None, **solver_kwargs):
        """Solve with a default :class:`repro.api.Solver`."""
        from .solver import Solver

        return Solver(opts, **solver_kwargs).solve(self)


def _flatten_with_keys(p: Problem):
    return (
        tuple((jax.tree_util.GetAttrKey(f), getattr(p, f)) for f in _LEAF_FIELDS),
        tuple(getattr(p, f) for f in _AUX_FIELDS),
    )


def _unflatten(aux, leaves):
    kw = dict(zip(_LEAF_FIELDS, leaves))
    kw.update(dict(zip(_AUX_FIELDS, aux)))
    # bypass __post_init__ validation: leaves may be tracers mid-transform
    obj = object.__new__(Problem)
    for k, v in kw.items():
        object.__setattr__(obj, k, v)
    object.__setattr__(obj, "graph", None)
    return obj


jax.tree_util.register_pytree_with_keys(Problem, _flatten_with_keys, _unflatten)
