"""repro.api — the canonical public surface for graph-LP solving.

Every graph LP (matching, vertex cover, dominating set, densest
subgraph, generalized matching) is a declarative :class:`Problem`; one
:class:`Solver` facade runs the MWU feasibility core over it — jitted,
optionally io_callback-traced, and vmap-batched across binary-search
bounds and graph instances. Build Problems with the pure builders in
:mod:`repro.graphs.problems` (or by hand from :mod:`repro.core`
operators), then::

    from repro.api import Solver
    from repro.graphs import build, rgg

    sol = Solver().solve(build("match", rgg(10)))
    print(sol.objective, sol.feasibility_calls)

The legacy entry points (``core.solve`` / ``solve_traced``, the
``core.feasibility`` binary-search drivers, ``ProblemLP.solve``) remain
as thin shims over this module. For serving mixed-size request traffic
through one compiled shape per bucket, see :mod:`repro.lpserve`.

``MWUOptions.kernel_backend`` selects the compute path for the MWU
iteration's hot ops (incidence gather, softmax weights, line-search
probe, fused axpy): ``"auto"`` (default) uses the Pallas kernel pack on
TPU and plain XLA elsewhere, ``"pallas"`` forces the kernels (interpret
mode off-TPU, for CI parity), ``"xla"`` forces the legacy jnp path.
The ``REPRO_KERNEL_BACKEND`` environment variable overrides ``"auto"``.
Resolution happens host-side per solve, so switching devices or env
between calls never hits a stale jit cache; see
:mod:`repro.kernels.dispatch`.
"""
from ..core.mwu import MWUOptions, MWUResult, Status
from .problem import BOUND_MODES, SENSES, Problem
from .solver import Solution, Solver, stack_problems

__all__ = [
    "Problem",
    "Solution",
    "Solver",
    "stack_problems",
    "MWUOptions",
    "MWUResult",
    "Status",
    "SENSES",
    "BOUND_MODES",
]
