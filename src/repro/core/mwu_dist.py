"""DEPRECATED distributed MWU entry points — thin shims over ``repro.dist``.

The 2-D grid-partitioned driver that used to live here (hand-rolled
while_loop with grid-transpose collectives over a (data, model) mesh) is
superseded by the mesh-sharded solver layer:

* :class:`repro.dist.MeshPlan` + :class:`repro.dist.DistSolver` run the
  SAME core driver (``core.mwu._run``) under ``shard_map`` with 1-D
  edge-slab sharding and psum-completed constraint rows;
* the legacy 2-D layout itself survives as
  :func:`repro.sparsela.partition.partition_edges` (host-side
  preprocessing, still covered by ``tests/test_distributed.py``).

These shims keep the old call signatures and result types alive by
translating onto the new layer; importing this module emits one
``DeprecationWarning`` per process. New code should use ``repro.dist``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..sparsela.partition import Partition2D
from ..utils.compat import shard_map
from ..utils.deprecation import warn_once
from .mwu import MWUOptions, _run
from .operators import Incidence, OnesRow

__all__ = ["dist_matching_solve", "DistMWUResult", "make_pod_parallel_solver"]

warn_once(
    "core.mwu_dist",
    "repro.core.mwu_dist is deprecated; use repro.dist (MeshPlan + DistSolver) "
    "for mesh-sharded solves",
)


class DistMWUResult(NamedTuple):
    x: jax.Array  # (G, G, e_cell) edge shards (legacy cell layout)
    status: jax.Array
    iters: jax.Array
    probes: jax.Array
    objective: jax.Array  # <1, x>
    max_px: jax.Array


def _flatten_partition(part: Partition2D):
    """Cell layout -> global edge list + the cell indices to scatter back."""
    mask = np.asarray(part.mask)
    i_idx, j_idx, k_idx = np.nonzero(mask)
    u = np.asarray(part.u_loc)[i_idx, j_idx, k_idx] + i_idx * part.block
    v = np.asarray(part.v_loc)[i_idx, j_idx, k_idx] + j_idx * part.block
    return u.astype(np.int32), v.astype(np.int32), (i_idx, j_idx, k_idx)


def dist_matching_solve(part: Partition2D, n_vertices: int, bound: float,
                        mesh, eps: float = 0.1, max_iter: int = 5000):
    """Feasibility solve: exists x >= 0 with Mx <= 1, <1,x> >= bound.

    Deprecated shim: flattens the legacy 2-D cell partition back into a
    global edge list and runs :class:`repro.dist.DistSolver` with an
    edge-slab pod plan over all of ``mesh``'s devices. The result keeps
    the old (G, G, e_cell) x layout.
    """
    from ..api.problem import Problem
    from ..dist import DistSolver, MeshPlan

    u, v, cell_idx = _flatten_partition(part)
    prob = Problem(
        name="match",
        kind="packing",
        sense="max",
        bound_mode="objective_covering",
        P=Incidence(u=jnp.asarray(u), v=jnp.asarray(v), n_vertices=int(n_vertices)),
        c=jnp.ones((u.shape[0],), jnp.float32),
        lo=1.0,
        hi=float(bound),
        n_vars=int(u.shape[0]),
        nnz=2 * int(u.shape[0]),
    )
    n_devices = int(np.asarray(mesh.devices).size) if mesh is not None else 1
    solver = DistSolver(
        MWUOptions(eps=eps, step_rule="binary", max_iter=max_iter),
        plan=MeshPlan(pod=n_devices, data=1),
    )
    res = solver.feasible(prob, float(bound))
    x_flat = np.asarray(res.x)
    x_cells = np.zeros((part.grid, part.grid, part.e_cell), x_flat.dtype)
    x_cells[cell_idx] = x_flat
    return DistMWUResult(
        x=jnp.asarray(x_cells),
        status=res.status,
        iters=res.iters,
        probes=res.ls_probes,
        objective=jnp.asarray(x_flat.sum()),
        max_px=res.max_px,
    )


def make_pod_parallel_solver(mesh, G: int, block: int, n_vertices: int,
                             n_edges: int, eps: float = 0.1, max_iter: int = 5000,
                             ls_cap: int = 60):
    """Pod-parallel bound search (beyond-paper, DESIGN.md §5). Deprecated.

    Returns a jittable ``fn(bounds (n_pod,), u, v, mask) -> (status,
    iters, objective, max_px)``, each ``(n_pod,)``: every pod tests a
    different bound concurrently. The shim reassembles the legacy
    (G, G, e_cell) cell shards into a global edge list IN-graph (the
    inputs are replicated across the pod's data/model axes) and runs the
    unified core driver per pod — no cross-pod collectives, so pods
    finish independently. ``ls_cap`` is accepted for signature
    compatibility; the core step rules carry their own probe caps.

    New code: ``repro.dist.DistSolver.solve_batch`` with a ``data``-axis
    plan does the same fan-out over any problem family.
    """
    del ls_cap  # legacy knob of the hand-rolled line search
    n_pad = G * block
    opts = MWUOptions(eps=eps, step_rule="binary", max_iter=max_iter)
    p_mask = jnp.arange(n_pad) < n_vertices  # padded vertex rows stay out of smax

    def inner(bound_loc, u, v, msk):
        u_g = (u + jnp.arange(G, dtype=u.dtype)[:, None, None] * block).reshape(-1)
        v_g = (v + jnp.arange(G, dtype=v.dtype)[None, :, None] * block).reshape(-1)
        em = msk.reshape(-1)
        P_op = Incidence(u=u_g, v=v_g, n_vertices=n_pad, edge_mask=em)
        C_op = OnesRow(
            c=jnp.where(em, 1.0, 0.0).astype(jnp.float32),
            inv_bound=(1.0 / bound_loc[0]).astype(jnp.float32),
        )
        res = _run(P_op, C_op, opts, p_mask, None)
        obj = jnp.sum(jnp.where(em, res.x, 0.0))
        return res.status[None], res.iters[None], obj[None], res.max_px[None]

    def fn(bounds, u, v, msk):
        return shard_map(
            inner,
            mesh=mesh,
            in_specs=(P("pod"), P(), P(), P()),
            out_specs=(P("pod"),) * 4,
            # per-pod results are replicated over the pod's own data/model
            # axes (inputs replicated, no collectives in the body) — not
            # expressible to the static rep checker.
            check_vma=False,
        )(bounds, u, v, msk)

    return fn
