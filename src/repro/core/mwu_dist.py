"""Distributed MWU on the 2-D incidence layout (paper §5.2 on TPU mesh).

Implements the paper's flagship distributed workload — maximum-matching
LP (pure packing, objective embedded as the single covering row) — with
every vector op sharded:

  * x, d, g        edge-space: sharded over the full G x G grid cell
  * y = Mx, w      vertex-space: block-sharded over "data", replicated
                   over "model"
  * z = <1,x>/Mb   scalar (the objective covering row), replicated

One ``shard_map`` region wraps the entire jitted ``lax.while_loop``
solve: per MWU iteration the only communication is 2 psums + 2 grid
transposes of (n/G)-sized blocks (the paper's O(n/sqrt p) bound) plus
scalar psums in the line search — there is no gather of the edge space
anywhere.

Step rule: exponential + binary search (Alg. 3) with completion
refinement, evaluated on distributed logsumexp probes.

The same entry point drives (a) multi-device CPU tests (4/8 host
devices, vs the single-device oracle), (b) the production-mesh dry-run
('mwu-graph' cell), and (c) the Fig. 4-style scaling benchmark.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..sparsela.distributed import mtw_local, mx_local
from ..sparsela.partition import Partition2D
from ..utils.compat import shard_map
from .mwu import Status, make_eta

__all__ = ["dist_matching_solve", "DistMWUResult"]

_AXES = ("data", "model")


class DistMWUResult(NamedTuple):
    x: jax.Array  # (G, G, e_cell) edge shards
    status: jax.Array
    iters: jax.Array
    probes: jax.Array
    objective: jax.Array  # <1, x>
    max_px: jax.Array


def _vlse(a_loc, mask_loc):
    """Distributed logsumexp over vertex blocks (row-sharded, model-replicated)."""
    a = jnp.where(mask_loc, a_loc, -jnp.inf)
    m_loc = jnp.max(a)
    m = lax.pmax(m_loc, _AXES[0])
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    s = lax.psum(jnp.sum(jnp.exp(a - m)), _AXES[0])
    return m + jnp.log(s), m, s


def _local_body(G, block, n, eta, eps, inv_bound, max_iter,
                u_loc, v_loc, emask, i_blk, ls_cap=60, sync_axis=None):
    """Returns the per-device while-loop solve (closed over static shapes).

    ``ls_cap`` bounds the line-search loops. The default 60 is a safety
    cap; the dry-run lowers with the measured average (~8, Table 3) so
    the roofline's while-trip accounting reflects expected cost, not the
    worst case."""
    vmask = (i_blk * block + jnp.arange(block)) < n  # real-vertex mask

    def psum_all(s):
        return lax.psum(s, _AXES)

    def probe_psi(y_loc, dy_loc, alpha, lse_y0):
        lse, _, _ = _vlse(eta * (y_loc + alpha * dy_loc), vmask)
        return (lse - lse_y0) / eta

    def step_search(y_loc, dy_loc, z, dz, lse_y0, alpha0):
        """Alg. 3 on distributed probes, warm-started at the previous
        step size (paper §4.2). Phi(a) = a*dz exactly (1 cover row)."""

        def f_of(a):
            psi = probe_psi(y_loc, dy_loc, a, lse_y0)
            return jnp.where(psi <= 1e-30, jnp.inf, (a * dz) / jnp.maximum(psi, 1e-30))

        def min_z(a):
            return z + a * dz

        one = jnp.maximum(alpha0, 1.0)
        f1 = f_of(one)

        # upward doubling
        def up_cond(s):
            a, f, k = s
            return (f >= 1) & (min_z(a) < 1) & (k < ls_cap)

        def up_body(s):
            a, f, k = s
            return a * 2, f_of(a * 2), k + 1

        a_up, f_up, k_up = lax.while_loop(up_cond, up_body, (one, f1, jnp.zeros((), jnp.int32)))
        completed_up = (f_up >= 1) & (min_z(a_up) >= 1)

        # downward halving (f(1) < 1)
        def dn_cond(s):
            a, f, k = s
            return (f < 1) & (a > 1e-12) & (k < ls_cap)

        def dn_body(s):
            a, f, k = s
            return a / 2, f_of(a / 2), k + 1

        a_dn, f_dn, k_dn = lax.while_loop(dn_cond, dn_body, (one, f1, jnp.zeros((), jnp.int32)))
        need_down = f1 < 1
        lb = jnp.where(need_down, a_dn, a_up / 2)
        ub = jnp.where(need_down, a_dn * 2, a_up)

        def bin_cond(s):
            lb, ub, k, done = s
            return (~done) & (ub - lb > eps * lb) & (k < ls_cap)

        def bin_body(s):
            lb, ub, k, done = s
            beta = 0.5 * (lb + ub)
            ok = f_of(beta) >= 1
            done = ok & (min_z(beta) >= 1)
            return jnp.where(ok, beta, lb), jnp.where(ok, ub, beta), k + 1, done

        lb, ub, k_bin, _ = lax.while_loop(
            bin_cond, bin_body, (lb, ub, jnp.zeros((), jnp.int32), completed_up)
        )
        alpha = jnp.where(completed_up, a_up, lb)

        # completion refinement: smallest alpha with z + alpha dz >= 1
        completes = min_z(alpha) >= 1

        def ref_cond(s):
            lo, hi, k = s
            return (hi - lo > eps * hi) & (k < ls_cap)

        def ref_body(s):
            lo, hi, k = s
            mid = 0.5 * (lo + hi)
            ok = min_z(mid) >= 1
            return jnp.where(ok, lo, mid), jnp.where(ok, mid, hi), k + 1

        lo, hi, k_ref = lax.while_loop(
            ref_cond, ref_body, (jnp.zeros_like(alpha), alpha, jnp.zeros((), jnp.int32))
        )
        alpha = jnp.where(completes, jnp.maximum(hi, 1.0), alpha)
        probes = k_up + k_dn + k_bin + k_ref
        return alpha, probes, completes

    def body(carry):
        x_loc, y_loc, z, it, probes, status, alpha_prev = carry
        # lockstep guard: when another pod is still solving, finished
        # pods keep executing (collective counts must stay aligned in a
        # single SPMD program) but freeze their own state.
        frozen = (status != Status.RUNNING) | (z >= 1.0)
        # packing weights w = softmax(eta y) over real vertices
        lse_y, m, s_loc = _vlse(eta * y_loc, vmask)
        w_loc = jnp.where(vmask, jnp.exp(eta * y_loc - lse_y), 0.0)
        # g = M^T w (edge shards); h = inv_bound (objective row)
        g_loc = mtw_local(u_loc, v_loc, emask, w_loc, G, _AXES)
        ratio = g_loc / inv_bound
        d_loc = (1.0 / eta) * jnp.maximum(0.0, 1.0 - ratio) * x_loc  # pure: 1/eta
        d_loc = jnp.where(emask, d_loc, 0.0)
        max_d = lax.pmax(jnp.max(d_loc), _AXES)
        infeasible_dir = max_d <= 0

        dy_loc = mx_local(u_loc, v_loc, emask, d_loc, block, G, _AXES)
        dz = psum_all(jnp.sum(d_loc)) * inv_bound

        alpha, k, completes = step_search(y_loc, dy_loc, z, dz, lse_y, alpha_prev)
        infeasible_alpha = alpha < 1
        bad = infeasible_dir | infeasible_alpha
        aa = jnp.where(bad, 0.0, alpha)
        x2 = x_loc + aa * d_loc
        y2 = y_loc + aa * dy_loc
        z2 = z + aa * dz
        new_status = jnp.where(bad, jnp.int32(Status.INFEASIBLE), jnp.int32(Status.RUNNING))
        ap2 = jnp.where(bad, alpha_prev, alpha)
        # freeze finished pods
        fz = lambda old, new: jnp.where(frozen, old, new)
        return (fz(x_loc, x2), fz(y_loc, y2), fz(z, z2), fz(it, it + 1),
                fz(probes, probes + k), fz(status, new_status), fz(alpha_prev, ap2))

    def cond(carry):
        x_loc, y_loc, z, it, probes, status, alpha_prev = carry
        run = (status == Status.RUNNING) & (z < 1.0) & (it < max_iter)
        if sync_axis is not None:
            # continue while ANY pod is running (lockstep across pods)
            run = lax.pmax(run.astype(jnp.int32), sync_axis) > 0
        return run

    return cond, body, vmask


def _dist_solve_local(G, block, n, eta, eps, inv_bound, max_iter,
                      u_loc, v_loc, emask, x0_loc, ls_cap=60, sync_axis=None):
    i_blk = lax.axis_index(_AXES[0])
    cond, body, vmask = _local_body(
        G, block, n, eta, eps, inv_bound, max_iter, u_loc, v_loc, emask, i_blk,
        ls_cap, sync_axis,
    )
    y0 = mx_local(u_loc, v_loc, emask, x0_loc, block, G, _AXES)
    z0 = lax.psum(jnp.sum(jnp.where(emask, x0_loc, 0.0)), _AXES) * inv_bound
    carry = (
        x0_loc, y0, z0,
        jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
        jnp.int32(Status.RUNNING), jnp.ones((), jnp.float32),
    )
    x, y, z, it, probes, status, _ = lax.while_loop(cond, body, carry)
    covered = z >= 1.0
    max_px = lax.pmax(jnp.max(jnp.where(vmask, y, -jnp.inf)), _AXES[0])
    packed = max_px <= 1.0 + eps + 1e-9
    final = jnp.where(
        status == Status.INFEASIBLE,
        jnp.int32(Status.INFEASIBLE),
        jnp.where(covered & packed, jnp.int32(Status.FEASIBLE), jnp.int32(Status.ITER_LIMIT)),
    )
    obj = lax.psum(jnp.sum(jnp.where(emask, x, 0.0)), _AXES)
    return x, final, it, probes, obj, max_px


def dist_matching_solve(part: Partition2D, n_vertices: int, bound: float,
                        mesh, eps: float = 0.1, max_iter: int = 5000):
    """Feasibility solve: exists x >= 0 with Mx <= 1, <1,x> >= bound.

    Returns DistMWUResult. Feasible => a matching LP objective >= bound
    is achievable (binary-search driver in benchmarks/examples).
    """
    G = part.grid
    m_rows = n_vertices + 1
    eta = jnp.asarray(make_eta(m_rows, eps), jnp.float32)
    inv_bound = jnp.asarray(1.0 / bound, jnp.float32)
    # init x = eps / (m_cols * colmax) with colmax=1 for incidence
    n_edges_pad = G * G * part.e_cell
    x0_val = eps / float(part.mask.sum())

    local = functools.partial(
        _dist_solve_local, G, part.block, n_vertices, eta, eps, inv_bound, max_iter
    )

    # shard_map local shards arrive as (1, 1, e_cell); squeeze inside.
    def wrapper(u, v, msk, x0):
        def inner(u, v, msk, x0):
            out = local(u[0, 0], v[0, 0], msk[0, 0], x0[0, 0])
            x, *rest = out
            return (x[None, None], *rest)

        return shard_map(
            inner,
            mesh=mesh,
            in_specs=(P("data", "model", None),) * 4,
            out_specs=(P("data", "model", None), P(), P(), P(), P(), P()),
            # the grid transpose provably re-replicates values over the
            # model axis (see module docstring), which the static vma
            # checker cannot express — replication is asserted by tests.
            check_vma=False,
        )(u, v, msk, x0)

    u = jnp.asarray(part.u_loc)
    v = jnp.asarray(part.v_loc)
    msk = jnp.asarray(part.mask)
    x0 = jnp.where(msk, jnp.float32(x0_val), 0.0)
    with mesh:
        x, status, it, probes, obj, max_px = jax.jit(wrapper)(u, v, msk, x0)
    return DistMWUResult(
        x=x, status=status, iters=it, probes=probes, objective=obj, max_px=max_px
    )


def make_pod_parallel_solver(mesh, G: int, block: int, n_vertices: int,
                             n_edges: int, eps: float = 0.1, max_iter: int = 5000,
                             ls_cap: int = 60):
    """Pod-parallel bound search (beyond-paper, DESIGN.md §5).

    The binary search over the objective bound M is a sequence of
    *independent* feasibility solves; on a (pod, data, model) mesh each
    pod tests a different bound concurrently — the edge partition is
    replicated across pods, ``bounds`` is sharded over "pod", and the
    grid collectives (named data/model axes only) stay pod-local.

    Returns a jittable fn(bounds (n_pod,), u, v, mask) ->
    (status (n_pod,), iters, objective, max_px).
    """
    m_rows = n_vertices + 1
    eta = jnp.asarray(make_eta(m_rows, eps), jnp.float32)
    x0_val = jnp.float32(eps / max(n_edges, 1))

    def inner(bound_loc, u, v, msk):
        u, v, msk = u[0, 0], v[0, 0], msk[0, 0]
        inv_bound = 1.0 / bound_loc[0]
        x0 = jnp.where(msk, x0_val, 0.0)
        x, status, it, probes, obj, max_px = _dist_solve_local(
            G, block, n_vertices, eta, eps, inv_bound, max_iter, u, v, msk, x0,
            ls_cap=ls_cap, sync_axis="pod",
        )
        one = lambda s: s[None]
        return one(status), one(it), one(obj), one(max_px)

    def fn(bounds, u, v, msk):
        return shard_map(
            inner,
            mesh=mesh,
            in_specs=(P("pod"), P("data", "model", None), P("data", "model", None),
                      P("data", "model", None)),
            out_specs=(P("pod"),) * 4,
            check_vma=False,
        )(bounds, u, v, msk)

    return fn
