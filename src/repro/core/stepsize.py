"""Step-size search for MWU (paper §4, Algorithms 2-3).

Given the current constraint values y = Px, z = Cx and the step images
d_y = Pd, d_z = Cd, find the largest step size alpha such that the
*bang-for-buck* invariant holds (paper eq. 16):

    f(alpha) = Phi(alpha) / Psi(alpha) >= 1,

    Phi(alpha) = smin_eta(z + alpha d_z) - smin_eta(z)   (covering gain)
    Psi(alpha) = smax_eta(y + alpha d_y) - smax_eta(y)   (packing cost)

f is monotone decreasing in alpha (paper Prop. 4.2), so the maximal
feasible alpha is found by exponential + binary search (Algorithm 3), or
by a warm-started, safeguarded Newton iteration on g(alpha) = f(alpha)-1
with the closed-form derivative

    Psi'(alpha) = < softmax(eta (y + alpha d_y)), d_y >
    Phi'(alpha) = < softmax(-eta (z + alpha d_z)), d_z >.

All searches early-return as soon as min(z + alpha d_z) >= 1 while
f(alpha) >= 1 (Algorithm 3 line 4): that step completes the solve.

Everything here runs inside the jitted MWU while-loop, so the searches
are themselves ``lax.while_loop``s with iteration caps. Probe counts are
returned for the Table-3 statistics.

Probes dominate MWU runtime (Table 3: tens of probes per iteration, each
a multi-pass reduction over both constraint vectors). Under a pallas
:class:`~repro.kernels.dispatch.KernelPolicy`, :func:`make_probe_fn`
therefore routes every probe through the fused
``kernels.linesearch_probe`` sweep — one pass over (y, dy) and one over
(z, dz) yields Psi/Phi, their Newton slopes, and the completion test
``min(z + alpha dz)``, collapsing the ~6 m-length passes the XLA path
below reads per probe. Masked problems (padded lpserve rows) and the
default XLA policy keep the jnp path, which doubles as the kernel's
oracle.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import dispatch as _kd
from .smoothing import logsumexp_shifted

__all__ = ["StepSizeResult", "standard_step", "binary_search_step", "newton_step"]

_MAX_EXP_ITERS = 64  # 2^64 dynamic range is enough for any float32/64 alpha
_MAX_BIN_ITERS = 64
_MAX_NEWTON_ITERS = 30
_MAX_BACKOFF_ITERS = 64


class StepSizeResult(NamedTuple):
    alpha: jax.Array  # chosen step size (>= 1 on feasible instances)
    probes: jax.Array  # number of f(alpha) evaluations (Table 3 "step size iters")
    completes: jax.Array  # bool: this step satisfies all covering constraints


def _masked_min(v, mask):
    if mask is None:
        return jnp.min(v)
    return jnp.min(jnp.where(mask, v, jnp.inf))


class _Probe(NamedTuple):
    """f(alpha) and its pieces at one probe point."""

    f: jax.Array
    phi: jax.Array
    psi: jax.Array
    dphi: jax.Array
    dpsi: jax.Array
    min_z: jax.Array  # min of covering values at this alpha


def make_probe_fn(y, z, dy, dz, eta, p_mask=None, c_mask=None, with_grad=False):
    """Close over the iteration state; returns probe(alpha) -> _Probe.

    Dispatch (decided once, at trace time): unmasked problems under a
    pallas policy evaluate each probe as two fused ``linesearch_probe``
    kernel sweeps (packing side sign=+1, covering side sign=-1 — lse,
    Newton slope and min(z + alpha dz) in one read of each vector pair);
    otherwise the jnp path below computes the same quantities from
    shared-shift logsumexps.
    """
    tiny = jnp.asarray(jnp.finfo(y.dtype).tiny, y.dtype)

    if p_mask is None and c_mask is None and _kd.choose("probe", y) == "pallas":
        dt = y.dtype
        eta_ = jnp.asarray(eta, dt)
        zero = jnp.zeros((), dt)
        lse_y0, _, _ = _kd.probe_pallas(y, dy, zero, eta_, sign=1.0)
        lse_z0, _, _ = _kd.probe_pallas(z, dz, zero, eta_, sign=-1.0)

        def probe_kernel(alpha):
            lse_ya, dpsi, _ = _kd.probe_pallas(y, dy, alpha, eta_, sign=1.0)
            lse_za, dphi, min_z = _kd.probe_pallas(z, dz, alpha, eta_, sign=-1.0)
            psi = (lse_ya - lse_y0) / eta_
            phi = -(lse_za - lse_z0) / eta_  # smin = -lse(-eta z)/eta
            f = jnp.where(psi <= tiny, jnp.inf, phi / jnp.maximum(psi, tiny))
            # the kernel's Newton slopes are free; with_grad is moot here
            return _Probe(f=f, phi=phi, psi=psi, dphi=dphi, dpsi=dpsi, min_z=min_z)

        return probe_kernel

    ay = eta * y
    az = -eta * z
    if p_mask is not None:
        ay = jnp.where(p_mask, ay, -jnp.inf)
    if c_mask is not None:
        az = jnp.where(c_mask, az, -jnp.inf)
    lse_y0, _ = logsumexp_shifted(ay)
    lse_z0, _ = logsumexp_shifted(az)

    def probe(alpha):
        ya = eta * (y + alpha * dy)
        za = -eta * (z + alpha * dz)
        if p_mask is not None:
            ya = jnp.where(p_mask, ya, -jnp.inf)
        if c_mask is not None:
            za = jnp.where(c_mask, za, -jnp.inf)
        lse_ya, sy = logsumexp_shifted(ya)
        lse_za, sz = logsumexp_shifted(za)
        # Psi = smax(y+a dy) - smax(y);  Phi = smin(z+a dz) - smin(z)
        psi = (lse_ya - lse_y0) / eta
        phi = -(lse_za - lse_z0) / eta  # note smin = -lse(-eta z)/eta
        # covering must improve and packing must not decrease for the
        # invariant to be meaningful; on degenerate steps psi can be ~0.
        f = jnp.where(psi <= tiny, jnp.inf, phi / jnp.maximum(psi, tiny))
        if with_grad:
            wy = jnp.exp(ya - lse_ya)  # softmax(eta(y+a dy))
            wz = jnp.exp(za - lse_za)  # softmax(-eta(z+a dz))
            dpsi = jnp.dot(wy, dy)
            dphi = jnp.dot(wz, dz)
        else:
            dpsi = jnp.zeros((), y.dtype)
            dphi = jnp.zeros((), y.dtype)
        min_z = _masked_min(z + alpha * dz, c_mask)
        return _Probe(f=f, phi=phi, psi=psi, dphi=dphi, dpsi=dpsi, min_z=min_z)

    return probe


def standard_step(y, z, dy, dz, eta, p_mask=None, c_mask=None, ls_eps=0.1, alpha0=None):
    """The theoretical step alpha = 1 (Mahoney et al. implicit choice)."""
    one = jnp.ones((), y.dtype)
    min_z = _masked_min(z + dz, c_mask)
    return StepSizeResult(alpha=one, probes=jnp.zeros((), jnp.int32), completes=min_z >= 1)


def _refine_completion(probe, hi, ls_eps):
    """Smallest alpha in (0, hi] with min_z(alpha) >= 1 (monotone in alpha).

    The completing step must not overshoot: the potential argument only
    bounds smax(Px) by f0 + smin(Cx), so covering overshoot translates
    directly into packing violation beyond (1+eps). Bisect to within
    ls_eps relative width; the result still satisfies the bang-for-buck
    invariant because f is decreasing (smaller alpha => larger f).
    """

    def cond(s):
        lo, h, n = s
        return (h - lo > ls_eps * h) & (n < _MAX_BIN_ITERS)

    def body(s):
        lo, h, n = s
        mid = 0.5 * (lo + h)
        ok = probe(mid).min_z >= 1
        return jnp.where(ok, lo, mid), jnp.where(ok, mid, h), n + 1

    lo, h, n = jax.lax.while_loop(
        cond, body, (jnp.zeros_like(hi), hi, jnp.zeros((), jnp.int32))
    )
    return jnp.maximum(h, jnp.ones_like(h)), n


def binary_search_step(y, z, dy, dz, eta, p_mask=None, c_mask=None, ls_eps=0.1, alpha0=None):
    """Algorithm 3: exponential bracket + binary search, warm-startable.

    Returns the largest alpha with f(alpha) >= 1 up to relative width
    ls_eps. If that alpha is < 1 the caller must declare infeasibility
    (paper, Alg. 2 line 12).
    """
    probe = make_probe_fn(y, z, dy, dz, eta, p_mask, c_mask)
    dt = y.dtype
    a0 = jnp.ones((), dt) if alpha0 is None else jnp.maximum(alpha0.astype(dt), 1.0)

    p0 = probe(a0)
    n0 = jnp.ones((), jnp.int32)

    # --- upward exponential phase: double while f >= 1 ------------------
    def up_cond(s):
        a, p, n = s
        # stop on bracket (f < 1) or on covering completion (Alg. 3 line 4)
        return (p.f >= 1) & (p.min_z < 1) & (n < _MAX_EXP_ITERS)

    def up_body(s):
        a, p, n = s
        a2 = a * 2
        return a2, probe(a2), n + 1

    a_up, p_up, n_up = jax.lax.while_loop(up_cond, up_body, (a0, p0, n0))
    completed_up = (p_up.f >= 1) & (p_up.min_z >= 1)

    # --- downward exponential phase (warm start overshot): halve while f < 1
    def dn_cond(s):
        a, p, n = s
        return (p.f < 1) & (a > 1e-12) & (n < _MAX_EXP_ITERS)

    def dn_body(s):
        a, p, n = s
        a2 = a / 2
        return a2, probe(a2), n + 1

    need_down = p0.f < 1
    a_dn, p_dn, n_dn = jax.lax.while_loop(
        dn_cond, dn_body, (a0, p0, jnp.zeros((), jnp.int32))
    )

    # bracket [lb, ub] with f(lb) >= 1 > f(ub)
    lb = jnp.where(need_down, a_dn, a_up / 2)
    ub = jnp.where(need_down, a_dn * 2, a_up)
    n_exp = jnp.where(need_down, n0 + n_dn, n_up)

    # --- binary phase ----------------------------------------------------
    def bin_cond(s):
        lb, ub, n, done = s
        return (~done) & (ub - lb > ls_eps * lb) & (n < _MAX_BIN_ITERS)

    def bin_body(s):
        lb, ub, n, done = s
        beta = 0.5 * (lb + ub)
        p = probe(beta)
        ok = p.f >= 1
        done = ok & (p.min_z >= 1)
        lb = jnp.where(ok, beta, lb)
        ub = jnp.where(ok, ub, beta)
        return lb, ub, n + 1, done

    lb, ub, n_bin, _ = jax.lax.while_loop(
        bin_cond, bin_body, (lb, ub, jnp.zeros((), jnp.int32), completed_up)
    )

    alpha = jnp.where(completed_up, a_up, lb)
    # If this step completes the covering constraints, shrink it to the
    # *smallest* completing alpha so packing does not overshoot (1+eps).
    completes = _masked_min(z + alpha * dz, c_mask) >= 1

    def do_refine():
        return _refine_completion(probe, alpha, ls_eps)

    alpha, n_ref = jax.lax.cond(
        completes, do_refine, lambda: (alpha, jnp.zeros((), jnp.int32))
    )
    return StepSizeResult(alpha=alpha, probes=n_exp + n_bin + n_ref, completes=completes)


def newton_step(y, z, dy, dz, eta, p_mask=None, c_mask=None, ls_eps=0.1, alpha0=None):
    """Warm-started, safeguarded Newton on g(alpha) = f(alpha) - 1 (§4.2).

    After convergence, multiplicatively backs off by (1 - ls_eps) until the
    bang-for-buck invariant (16) holds, as the paper prescribes.
    """
    probe = make_probe_fn(y, z, dy, dz, eta, p_mask, c_mask, with_grad=True)
    dt = y.dtype
    a0 = jnp.ones((), dt) if alpha0 is None else jnp.maximum(alpha0.astype(dt), 1e-6)

    def nt_cond(s):
        a, p, n, done = s
        return (~done) & (n < _MAX_NEWTON_ITERS)

    def nt_body(s):
        a, p, n, done = s
        # f' = (Phi' Psi - Phi Psi') / Psi^2   (negative: f is decreasing)
        tiny = jnp.asarray(jnp.finfo(dt).tiny, dt)
        psi2 = jnp.maximum(p.psi * p.psi, tiny)
        fp = (p.dphi * p.psi - p.phi * p.dpsi) / psi2
        fp = jnp.minimum(fp, -tiny)  # enforce the known sign
        raw = a - (p.f - 1.0) / fp
        # trust-region safeguard: at most 8x move per iteration
        a2 = jnp.clip(raw, a * 0.125, a * 8.0)
        a2 = jnp.maximum(a2, 1e-12)
        p2 = probe(a2)
        done = (jnp.abs(a2 - a) <= ls_eps * a) | ((p2.f >= 1) & (p2.min_z >= 1))
        return a2, p2, n + 1, done

    p0 = probe(a0)
    a, p, n, _ = jax.lax.while_loop(nt_cond, nt_body, (a0, p0, jnp.ones((), jnp.int32), jnp.zeros((), bool)))

    # back off multiplicatively until invariant satisfied (paper §4.2)
    def bo_cond(s):
        a, p, n = s
        return (p.f < 1) & (n < _MAX_BACKOFF_ITERS)

    def bo_body(s):
        a, p, n = s
        a2 = a * (1.0 - ls_eps)
        return a2, probe(a2), n + 1

    a, p, n_bo = jax.lax.while_loop(bo_cond, bo_body, (a, p, jnp.zeros((), jnp.int32)))

    # completion refinement: smallest alpha that satisfies covering
    completes = (p.min_z >= 1) & (p.f >= 1)

    def do_refine():
        return _refine_completion(probe, a, ls_eps)

    a, n_ref = jax.lax.cond(completes, do_refine, lambda: (a, jnp.zeros((), jnp.int32)))
    return StepSizeResult(alpha=a, probes=n + n_bo + n_ref, completes=completes)


STEP_RULES = {
    "std": standard_step,
    "binary": binary_search_step,
    "newton": newton_step,
}
