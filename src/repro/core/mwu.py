"""MWU solver for mixed packing & covering LPs (paper Algorithms 1-2).

Feasibility problem (paper eq. 2):

    exists x >= 0  with  P x <= 1  and  C x >= 1,

P, C entrywise nonnegative ``LinOp``s. The solver returns a
(1+eps)-relative solution (P x <= (1+eps) 1, C x >= 1) or reports
INFEASIBLE, in O~(eps^-3) iterations (eps^-2 for pure problems).

One trace-unified driver serves every entry point: a single
``lax.while_loop`` (the whole solve is one XLA program; all vector work
between the two SpMVs of an iteration fuses, which is the XLA analogue
of the paper's §5.1.3 loop fusion) with an optional ``io_callback``
trace hook that streams per-iteration diagnostics (max violation, alpha,
probes) to the host for the Figure-3 convergence studies.

``MWUOptions.kernel_backend`` selects the vector-op implementation for
the loop body: under ``"pallas"`` (or ``"auto"`` on TPU) the incidence
gathers, the eta-softmax gradient weights, every line-search probe, and
the x/y/z update triple run through the fused Pallas kernel pack via
``repro.kernels.dispatch`` — the entry points resolve the backend
host-side (outside jit) into a :class:`~repro.kernels.dispatch.KernelPolicy`
static argument, so the jit cache can never serve a stale device choice,
and CPU runs exercise the identical kernel code in interpret mode.

* ``solve``        — the production path (trace hook off).
* ``solve_traced`` — same compiled loop with the trace hook on; kept as
                     a thin shim for legacy callers. The canonical
                     public surface is :mod:`repro.api` (``Solver`` /
                     ``Problem``), which also vmaps this driver across
                     binary-search bounds and graph instances.

State kept across iterations (paper Alg. 2 lines 3, 10, 15): x and the
constraint images y = Px, z = Cx and step images d_y = Pd, d_z = Cd, so
each iteration performs exactly two pairs of SpMVs (P/Pᵀ, C/Cᵀ) — never
recomputing Px from scratch.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import dispatch as _kd
from .operators import LinOp
from .smoothing import smax_and_weights, smin_and_weights
from .stepsize import STEP_RULES, StepSizeResult

__all__ = [
    "MWUOptions",
    "MWUResult",
    "Status",
    "solve",
    "solve_traced",
    "lower",
    "solve_jaxpr",
    "init_x",
    "make_eta",
]


class Status:
    RUNNING = 0
    FEASIBLE = 1
    INFEASIBLE = 2
    ITER_LIMIT = 3

    NAMES = {0: "RUNNING", 1: "FEASIBLE", 2: "INFEASIBLE", 3: "ITER_LIMIT"}


@dataclass(frozen=True)
class MWUOptions:
    """Static solver configuration (hashable -> usable as jit static arg)."""

    eps: float = 0.1
    max_iter: int = 5000  # paper §6.2
    step_rule: str = "newton"  # "std" | "binary" | "newton"
    ls_eps: float | None = None  # line-search relative tolerance (default: eps)
    eta_factor: float = 10.0  # eta = eta_factor * log(m) / eps (paper line 2)
    pure: bool | None = None  # None = auto-detect single-row objective embedding
    # packing slack accepted at termination; the theory gives (1+eps).
    check_packing: bool = True
    # vector-op backend for the loop body: "auto" (pallas on TPU, xla
    # elsewhere; REPRO_KERNEL_BACKEND env var overrides), "pallas"
    # (fused kernel pack, interpret mode off-TPU), or "xla".
    kernel_backend: str = "auto"

    def resolve_pure(self, P: LinOp, C: LinOp) -> bool:
        if self.pure is not None:
            return self.pure
        return P.shape[0] == 1 or C.shape[0] == 1

    @property
    def ls_tol(self) -> float:
        return self.eps if self.ls_eps is None else self.ls_eps


class MWUResult(NamedTuple):
    x: jax.Array
    status: jax.Array  # int32 Status code
    iters: jax.Array  # MWU iterations executed
    ls_probes: jax.Array  # total line-search probes (Table 3)
    max_px: jax.Array  # max_i (Px)_i at exit
    min_cx: jax.Array  # min_i (Cx)_i at exit

    @property
    def feasible(self):
        return self.status == Status.FEASIBLE


def make_eta(m: int, eps: float, eta_factor: float = 10.0):
    return eta_factor * np.log(max(m, 2)) / eps


def init_x(P: LinOp, eps: float, dtype, n_cols: int | None = None, axis=None) -> jax.Array:
    """x_i = eps / (n * ||P_{:,i}||_inf)  (paper Alg. 1 line 3).

    Guarantees every packing row starts at most eps. Columns absent from P
    (colmax = 0) would start unbounded; they are clamped to the max of the
    present columns' scale (only well-posed LPs reach us in practice).

    ``n_cols`` overrides the column count when ``P`` is a per-device
    shard of a wider operator (repro.dist slab sharding), so the init
    scale matches the single-device solve; ``axis`` names the mesh axis
    the fallback min must reduce over in that case.
    """
    n = P.shape[1] if n_cols is None else n_cols
    cm = P.colmax().astype(dtype)
    safe = jnp.where(cm > 0, cm, jnp.inf)
    x = eps / (n * safe)
    fallback = jnp.min(jnp.where(cm > 0, x, jnp.inf))
    if axis is not None:
        fallback = jax.lax.pmin(fallback, axis)
    fallback = jnp.where(jnp.isfinite(fallback), fallback, eps / n)
    return jnp.where(cm > 0, x, fallback).astype(dtype)


class _Carry(NamedTuple):
    x: jax.Array
    y: jax.Array
    z: jax.Array
    it: jax.Array
    probes: jax.Array
    alpha_prev: jax.Array
    status: jax.Array


def _masked_min(v, mask):
    return jnp.min(v) if mask is None else jnp.min(jnp.where(mask, v, jnp.inf))


def _masked_max(v, mask):
    return jnp.max(v) if mask is None else jnp.max(jnp.where(mask, v, -jnp.inf))


def _iteration(P: LinOp, C: LinOp, eta, scale, step_fn, ls_eps, p_mask, c_mask, axis, carry: _Carry) -> _Carry:
    """One MWU iteration (Alg. 2 body). Returns the updated carry.

    ``axis`` (a mesh axis name or None) marks an SPMD run where the
    variable space is slab-sharded across that axis (repro.dist): the
    only variable-space *global* reduction in the body — the
    infeasible-direction test on ``max(d)`` — then psum-completes via
    ``lax.pmax``. Constraint-space vectors (y, z, dy, dz) stay
    replicated across the axis (the sharded operators psum their
    matvec outputs), so the smoothing/step-size math needs no change.
    """
    x, y, z = carry.x, carry.y, carry.z
    dt = x.dtype
    tiny = jnp.asarray(jnp.finfo(dt).tiny, dt)

    # gradients of the smoothed constraint potentials (lines 5-6)
    _, wp = smax_and_weights(y, eta, where=p_mask)
    _, wc = smin_and_weights(z, eta, where=c_mask)
    g = P.rmatvec(wp)  # packing gradient  P^T grad smax(Px)
    h = C.rmatvec(wc)  # covering gradient C^T grad smin(Cx)

    # step direction (line 7): d_i = scale * max(0, 1 - g_i/h_i) * x_i
    ratio = jnp.where(h > tiny, g / jnp.maximum(h, tiny), jnp.inf)
    d = scale * jnp.maximum(0.0, 1.0 - ratio) * x

    max_d = jnp.max(d)
    if axis is not None:
        max_d = jax.lax.pmax(max_d, axis)
    infeasible_dir = max_d <= 0  # line 8

    # step images (line 10) — the second SpMV pair
    dy = P.matvec(d)
    dz = C.matvec(d)

    # step size (line 11)
    ss: StepSizeResult = step_fn(y, z, dy, dz, eta, p_mask, c_mask, ls_eps, carry.alpha_prev)
    infeasible_alpha = ss.alpha < 1  # line 12

    # apply (lines 14-15); never move on a terminal iteration. Under a
    # pallas policy the update triple runs as fused axpy+reduce sweeps
    # (the min/max come free; XLA DCEs them on the fallback path).
    bad = infeasible_dir | infeasible_alpha
    aa = jnp.where(bad, 0.0, ss.alpha).astype(dt)
    if _kd.choose("axpy", x) == "pallas":
        x2, _, _ = _kd.axpy_pallas(x, d, aa)
        y2, _, _ = _kd.axpy_pallas(y, dy, aa)
        z2, _, _ = _kd.axpy_pallas(z, dz, aa)
    else:
        x2 = x + aa * d
        y2 = y + aa * dy
        z2 = z + aa * dz

    status = jnp.where(
        infeasible_dir | infeasible_alpha,
        jnp.int32(Status.INFEASIBLE),
        jnp.int32(Status.RUNNING),
    )
    return _Carry(
        x=x2,
        y=y2,
        z=z2,
        it=carry.it + 1,
        probes=carry.probes + ss.probes,
        alpha_prev=jnp.where(bad, carry.alpha_prev, ss.alpha.astype(dt)),
        status=status,
    )


def _finalize(opts: MWUOptions, carry: _Carry, p_mask, c_mask) -> MWUResult:
    max_px = _masked_max(carry.y, p_mask)
    min_cx = _masked_min(carry.z, c_mask)
    covered = min_cx >= 1.0
    packed = (max_px <= 1.0 + opts.eps + 1e-9) | (not opts.check_packing)
    status = jnp.where(
        carry.status == Status.INFEASIBLE,
        jnp.int32(Status.INFEASIBLE),
        jnp.where(
            covered & packed,
            jnp.int32(Status.FEASIBLE),
            jnp.int32(Status.ITER_LIMIT),
        ),
    )
    return MWUResult(
        x=carry.x,
        status=status,
        iters=carry.it,
        ls_probes=carry.probes,
        max_px=max_px,
        min_cx=min_cx,
    )


class _TraceSink:
    """Host-side accumulator fed by the in-loop ``io_callback`` hook.

    Rows are (iteration, violation, alpha, probes) tuples; the iteration
    index makes row order irrelevant, so the callback can stay unordered
    (ordered effects are not supported inside ``lax.while_loop``).
    Not thread-safe: one traced solve at a time.
    """

    def __init__(self):
        self.rows: list | None = None


_TRACE = _TraceSink()


def _trace_emit(it, viol, alpha, probes):
    if _TRACE.rows is not None:
        _TRACE.rows.append((int(it), float(viol), float(alpha), int(probes)))


def _run(
    P: LinOp,
    C: LinOp,
    opts: MWUOptions,
    pm,
    cm,
    trace: bool = False,
    kernels=None,
    axis=None,
    init_cols=None,
):
    """The unified driver: one ``lax.while_loop`` for jit, vmap and tracing.

    Masks are None-or-array at the python level (callers that need a
    pytree-stable jit signature pass dummies through ``_solve_impl``).
    With ``trace=True`` each iteration emits (it, violation, alpha,
    probes) through an unordered ``io_callback`` into ``_TRACE``; the
    hook must stay off under ``jax.vmap`` (io_callback has no batching
    rule by default), which ``repro.api`` enforces.

    ``kernels`` is the resolved :class:`~repro.kernels.dispatch.KernelPolicy`
    installed for the duration of this trace; the public entry points
    resolve it host-side and pass it through as a jit static argument.
    Direct callers that omit it get a trace-time resolution fallback.

    ``axis``/``init_cols`` are set only by :mod:`repro.dist` when the
    variable space is slab-sharded across a mesh axis: ``axis`` names
    the axis for the two variable-space collectives (init fallback min,
    infeasible-direction max), ``init_cols`` is the *global* column
    count so the init scale matches the single-device solve.
    """
    policy = kernels if kernels is not None else _kd.resolve(opts.kernel_backend)
    with _kd.use_policy(policy):
        return _run_inner(P, C, opts, pm, cm, trace, axis, init_cols)


def _run_inner(P: LinOp, C: LinOp, opts: MWUOptions, pm, cm, trace: bool, axis=None, init_cols=None):
    m = P.shape[0] + C.shape[0]
    dt = jnp.promote_types(P.colmax().dtype, C.colmax().dtype)
    dt = dt if jnp.issubdtype(dt, jnp.floating) else jnp.float32
    eta = jnp.asarray(make_eta(m, opts.eps, opts.eta_factor), dt)
    # pure packing/covering admit a 2x larger step scale (paper §2.2)
    scale = (1.0 if opts.resolve_pure(P, C) else 0.5) / eta
    step_fn = STEP_RULES[opts.step_rule]

    x0 = init_x(P, opts.eps, dt, n_cols=init_cols, axis=axis)
    carry0 = _Carry(
        x=x0,
        y=P.matvec(x0).astype(dt),
        z=C.matvec(x0).astype(dt),
        it=jnp.zeros((), jnp.int32),
        probes=jnp.zeros((), jnp.int32),
        alpha_prev=jnp.ones((), dt),
        status=jnp.int32(Status.RUNNING),
    )

    def cond(carry: _Carry):
        done_cover = _masked_min(carry.z, cm) >= 1.0
        return (
            (carry.status == Status.RUNNING)
            & (~done_cover)
            & (carry.it < opts.max_iter)
        )

    iter_body = partial(_iteration, P, C, eta, scale, step_fn, opts.ls_tol, pm, cm, axis)

    if trace:
        from jax.experimental import io_callback

        def body(carry: _Carry) -> _Carry:
            nxt = iter_body(carry)
            viol = jnp.maximum(
                jnp.maximum(_masked_max(carry.y, pm) - 1.0, 1.0 - _masked_min(carry.z, cm)),
                0.0,
            )
            io_callback(_trace_emit, None, carry.it, viol, nxt.alpha_prev, nxt.probes - carry.probes)
            return nxt

    else:
        body = iter_body

    carry = jax.lax.while_loop(cond, body, carry0)
    return _finalize(opts, carry, pm, cm)


@partial(jax.jit, static_argnames=("opts", "has_p_mask", "has_c_mask", "trace", "kernels"))
def _solve_impl(P, C, opts: MWUOptions, p_mask, c_mask, has_p_mask, has_c_mask, trace=False, kernels=None):
    pm = p_mask if has_p_mask else None
    cm = c_mask if has_c_mask else None
    return _run(P, C, opts, pm, cm, trace=trace, kernels=kernels)


def _mask_args(P, C, p_mask, c_mask):
    """Dummy-mask plumbing shared by solve / solve_traced / lower.

    Masks are passed as dummies when absent so the jit signature stays
    pytree-stable; the has_* statics select whether they are real.
    """
    hp, hc = p_mask is not None, c_mask is not None
    pm = p_mask if hp else jnp.zeros((P.shape[0],), bool)
    cmk = c_mask if hc else jnp.zeros((C.shape[0],), bool)
    return pm, cmk, hp, hc


def solve(P: LinOp, C: LinOp, opts: MWUOptions = MWUOptions(), p_mask=None, c_mask=None) -> MWUResult:
    """Solve the feasibility LP  P x <= 1, C x >= 1, x >= 0  (fully jitted)."""
    pm, cmk, hp, hc = _mask_args(P, C, p_mask, c_mask)
    # Resolve the kernel backend OUTSIDE the jit: the concrete policy is
    # part of the cache key, so a device switch re-resolves instead of
    # serving a stale trace-time jax.default_backend() read.
    kernels = _kd.resolve(opts.kernel_backend)
    return _solve_impl(P, C, opts, pm, cmk, hp, hc, kernels=kernels)


def lower(P: LinOp, C: LinOp, opts: MWUOptions = MWUOptions(), p_mask=None, c_mask=None, trace=False):
    """AOT-lower :func:`solve` without executing it (``jax.stages.Lowered``).

    Same jit entry, statics and dummy-mask plumbing as :func:`solve`, so
    what ``repro.tracecheck`` lints is byte-for-byte the program a real
    call would run. ``.compile().as_text()`` gives the optimized HLO.
    """
    pm, cmk, hp, hc = _mask_args(P, C, p_mask, c_mask)
    kernels = _kd.resolve(opts.kernel_backend)
    return _solve_impl.lower(P, C, opts, pm, cmk, hp, hc, trace=trace, kernels=kernels)


def solve_jaxpr(P: LinOp, C: LinOp, opts: MWUOptions = MWUOptions(), p_mask=None, c_mask=None, trace=False):
    """The ClosedJaxpr of the solve body (pre-compilation primitive view).

    Traces :func:`_run` directly (under the resolved kernel policy) so
    ``pallas_call`` / collective / callback primitives stay visible —
    the form the jaxpr-level tracecheck rules inspect.
    """
    pm, cmk, hp, hc = _mask_args(P, C, p_mask, c_mask)
    kernels = _kd.resolve(opts.kernel_backend)

    def fn(P, C, pm, cmk):
        return _run(
            P, C, opts,
            pm if hp else None, cmk if hc else None,
            trace=trace, kernels=kernels,
        )

    return jax.make_jaxpr(fn)(P, C, pm, cmk)


def solve_traced(P: LinOp, C: LinOp, opts: MWUOptions = MWUOptions(), p_mask=None, c_mask=None):
    """Tracing solve recording per-iteration diagnostics (Fig. 3).

    Same compiled ``lax.while_loop`` as :func:`solve`, with the
    ``io_callback`` trace hook enabled. Returns (MWUResult, trace) with
    trace = dict of numpy arrays: ``max_violation`` = max(0, max(Px)-1,
    1-min(Cx)) sampled at the start of every iteration (plus the final
    state when the loop exits before the iteration cap), ``alpha``,
    ``probes``.
    """
    pm, cmk, hp, hc = _mask_args(P, C, p_mask, c_mask)
    kernels = _kd.resolve(opts.kernel_backend)
    _TRACE.rows = []
    try:
        res = _solve_impl(P, C, opts, pm, cmk, hp, hc, trace=True, kernels=kernels)
        jax.block_until_ready(res.x)
        jax.effects_barrier()
        rows = sorted(_TRACE.rows)
    finally:
        _TRACE.rows = None

    viol = [r[1] for r in rows]
    alphas = [r[2] for r in rows]
    probes = [r[3] for r in rows]
    if int(res.iters) < opts.max_iter:
        # loop exited through its own condition: record the final state,
        # matching the python-stepped driver this replaced.
        viol.append(max(0.0, float(res.max_px) - 1.0, 1.0 - float(res.min_cx)))
    trace = {
        "max_violation": np.asarray(viol),
        "alpha": np.asarray(alphas),
        "probes": np.asarray(probes),
    }
    return res, trace
