"""Optimization via feasibility + binary search (paper §2.2, §3).

DEPRECATED SHIMS. The binary-search drivers that lived here are now the
``batch_width=1`` mode of the unified :class:`repro.api.Solver`; these
wrappers keep the original signatures and return types
(:class:`BinarySearchResult`) while delegating to the new path. New code
should build a declarative :class:`repro.api.Problem` and call
``Solver.solve`` — with ``batch_width > 1`` the binary-search branches
are evaluated speculatively in one vmapped XLA call (the DESIGN.md §5
pod-parallel bounds note), instead of sequentially as the paper does.

The reduction itself is unchanged: MWU solves *feasibility* mixed
packing/covering LPs; optimization embeds the objective as one extra
constraint row and binary-searches its bound:

* pure packing    max <c,x> : Px <= 1   ->  add covering row <c,x>/M >= 1
* pure covering   min <c,x> : Cx >= 1   ->  add packing  row <c,x>/M <= 1
* densest subgraph: binary search the density bound D of the dual (15).

Because there is a single objective row, smin (resp. smax) over it is
*exact*, which the theory rewards with a 2x step scale (handled by
``MWUOptions.pure`` auto-detection).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp
import numpy as np

from ..utils.deprecation import warn_once
from .mwu import MWUOptions, MWUResult
from .operators import LinOp

warn_once(
    "repro.core.feasibility",
    "repro.core.feasibility is deprecated; build a repro.api.Problem and use "
    "repro.api.Solver.solve (or repro.dist.DistSolver for mesh-sharded runs)",
)

__all__ = [
    "BinarySearchResult",
    "maximize_packing",
    "minimize_covering",
    "densest_subgraph_search",
]


@dataclass
class BinarySearchResult:
    x: np.ndarray  # best feasible solution found (original variable space)
    objective: float  # certified objective value of x (after 1+eps rescale)
    bound: float  # final binary-search bound
    feasibility_calls: int
    mwu_iters_total: int
    ls_probes_total: int
    last_result: MWUResult | None = None

    @property
    def found(self):
        return self.x is not None


def _from_solution(sol) -> BinarySearchResult:
    return BinarySearchResult(
        x=sol.x,
        objective=sol.objective if sol.found else 0.0,
        bound=sol.bound,
        feasibility_calls=sol.feasibility_calls,
        mwu_iters_total=sol.mwu_iters_total,
        ls_probes_total=sol.ls_probes_total,
        last_result=sol.last_result,
    )


def _solver(opts: MWUOptions, rel_tol):
    # imported lazily: repro.api imports repro.core at module load
    from ..api import Solver

    return Solver(opts, batch_width=1, rel_tol=rel_tol)


def maximize_packing(
    P: LinOp,
    c: jnp.ndarray,
    lo: float,
    hi: float,
    opts: MWUOptions = MWUOptions(),
    rel_tol: float | None = None,
) -> BinarySearchResult:
    """max <c, x>  s.t.  P x <= 1, x >= 0.  (deprecated shim)

    ``lo`` must be an achievable objective value, ``hi`` an upper bound
    (from a combinatorial heuristic, see graphs/baselines.py).
    Feasible at M means objective >= M is reachable with Px <= (1+eps);
    dividing x by (1+eps) certifies objective >= M/(1+eps).
    """
    from ..api import Problem

    c = jnp.asarray(c)
    prob = Problem(
        name="packing", kind="packing", sense="max", bound_mode="objective_covering",
        P=P, c=c, lo=float(lo), hi=float(hi), n_vars=P.shape[1], nnz=P.nnz,
    )
    return _from_solution(_solver(opts, rel_tol).solve(prob))


def minimize_covering(
    C: LinOp,
    c: jnp.ndarray,
    lo: float,
    hi: float,
    opts: MWUOptions = MWUOptions(),
    rel_tol: float | None = None,
) -> BinarySearchResult:
    """min <c, x>  s.t.  C x >= 1, x >= 0.  (deprecated shim)

    Feasible at M certifies opt <= M (1+eps); infeasible certifies opt > M.
    Searches the smallest feasible M in [lo, hi] at eps/2 granularity.
    """
    from ..api import Problem

    c = jnp.asarray(c)
    prob = Problem(
        name="covering", kind="covering", sense="min", bound_mode="objective_packing",
        C=C, c=c, lo=float(lo), hi=float(hi), n_vars=C.shape[1], nnz=C.nnz,
    )
    return _from_solution(_solver(opts, rel_tol).solve(prob))


def densest_subgraph_search(
    make_PC: Callable[[float], tuple[LinOp, LinOp]],
    lo: float,
    hi: float,
    opts: MWUOptions = MWUOptions(),
    rel_tol: float | None = None,
) -> BinarySearchResult:
    """min D s.t. the dual feasibility LP (15) is feasible.  (deprecated shim)

    ``make_PC(D)`` builds (P, C) = (O/D, W). Feasible iff D >= rho*
    (the maximum density), so we search the smallest feasible D
    (eps/2 granularity; see minimize_covering). Prefer the declarative
    ``graphs.problems.densest_subgraph_lp`` (bound_mode="scale_packing"),
    which admits batched bound evaluation.
    """
    from ..api import Problem

    prob = Problem(
        name="densest", kind="densest", sense="min", bound_mode="callable",
        make_ops=make_PC, lo=float(lo), hi=float(hi),
    )
    return _from_solution(_solver(opts, rel_tol).solve(prob))
