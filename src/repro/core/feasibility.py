"""Optimization via feasibility + binary search (paper §2.2, §3).

MWU solves *feasibility* mixed packing/covering LPs. Optimization
problems are reduced to a sequence of feasibility questions by embedding
the objective as one extra constraint row and binary-searching its bound:

* pure packing    max <c,x> : Px <= 1   ->  add covering row <c,x>/M >= 1
* pure covering   min <c,x> : Cx >= 1   ->  add packing  row <c,x>/M <= 1
* densest subgraph: binary search the density bound D of the dual (15).

Because there is a single objective row, smin (resp. smax) over it is
*exact*, which the theory rewards with a 2x step scale (handled by
``MWUOptions.pure`` auto-detection).

Beyond-paper note (DESIGN.md §5): the binary-search branches are
independent feasibility solves, so at pod scale the ``pod`` mesh axis can
evaluate different bounds concurrently; here the reference driver runs
them sequentially exactly as the paper does.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp
import numpy as np

from .mwu import MWUOptions, MWUResult, Status, solve
from .operators import LinOp, OnesRow, ScaledRows

__all__ = [
    "BinarySearchResult",
    "maximize_packing",
    "minimize_covering",
    "densest_subgraph_search",
]


@dataclass
class BinarySearchResult:
    x: np.ndarray  # best feasible solution found (original variable space)
    objective: float  # certified objective value of x (after 1+eps rescale)
    bound: float  # final binary-search bound
    feasibility_calls: int
    mwu_iters_total: int
    ls_probes_total: int
    last_result: MWUResult | None = None

    @property
    def found(self):
        return self.x is not None


def _bsearch(check: Callable[[float], tuple[bool, MWUResult]], lo: float, hi: float, rel_tol: float):
    """Generic geometric binary search; check(bound) -> (feasible, result).

    Maintains lo = best known feasible-side bound, hi = infeasible side
    (direction depends on the caller's convention).
    """
    calls = iters = probes = 0
    best = None
    while hi / max(lo, 1e-300) > 1.0 + rel_tol and calls < 64:
        mid = float(np.sqrt(lo * hi))
        ok, res = check(mid)
        calls += 1
        iters += int(res.iters)
        probes += int(res.ls_probes)
        if ok:
            lo, best = mid, res
        else:
            hi = mid
    return lo, hi, best, calls, iters, probes


def maximize_packing(
    P: LinOp,
    c: jnp.ndarray,
    lo: float,
    hi: float,
    opts: MWUOptions = MWUOptions(),
    rel_tol: float | None = None,
) -> BinarySearchResult:
    """max <c, x>  s.t.  P x <= 1, x >= 0.

    ``lo`` must be an achievable objective value, ``hi`` an upper bound
    (from a combinatorial heuristic, see graphs/baselines.py).
    Feasible at M means objective >= M is reachable with Px <= (1+eps);
    dividing x by (1+eps) certifies objective >= M/(1+eps).

    The bound search runs at eps/2 so its granularity does not compound
    the solver's eps past the paper's acceptance band.
    """
    rel_tol = opts.eps / 2 if rel_tol is None else rel_tol
    c = jnp.asarray(c)

    def check(M):
        C = OnesRow(c=c, inv_bound=jnp.asarray(1.0 / M, c.dtype))
        res = solve(P, C, opts)
        return bool(res.status == Status.FEASIBLE), res

    lo2, hi2, best, calls, iters, probes = _bsearch(check, lo, hi, rel_tol)
    if best is None:  # even `lo` failed as a strict bound; retry at lo
        ok, best = check(lo)
        calls += 1
        iters += int(best.iters)
        probes += int(best.ls_probes)
        if not ok:
            return BinarySearchResult(None, 0.0, lo, calls, iters, probes, best)
    scale = 1.0 + float(best.max_px - 1.0) if float(best.max_px) > 1.0 else 1.0
    x = np.asarray(best.x) / scale
    obj = float(jnp.dot(c, jnp.asarray(x)))
    return BinarySearchResult(x, obj, lo2, calls, iters, probes, best)


def minimize_covering(
    C: LinOp,
    c: jnp.ndarray,
    lo: float,
    hi: float,
    opts: MWUOptions = MWUOptions(),
    rel_tol: float | None = None,
) -> BinarySearchResult:
    """min <c, x>  s.t.  C x >= 1, x >= 0.

    Feasible at M certifies opt <= M (1+eps); infeasible certifies opt > M.
    Searches the smallest feasible M in [lo, hi] at eps/2 granularity.
    """
    rel_tol = opts.eps / 2 if rel_tol is None else rel_tol
    c = jnp.asarray(c)
    calls = iters = probes = 0
    best = None
    best_M = hi

    def check(M):
        P = OnesRow(c=c, inv_bound=jnp.asarray(1.0 / M, c.dtype))
        res = solve(P, C, opts)
        return bool(res.status == Status.FEASIBLE), res

    lo_b, hi_b = lo, hi
    # invariant: hi_b feasible (checked first), lo_b infeasible-or-unknown
    ok, res = check(hi_b)
    calls += 1
    iters += int(res.iters)
    probes += int(res.ls_probes)
    if not ok:
        return BinarySearchResult(None, 0.0, hi_b, calls, iters, probes, res)
    best, best_M = res, hi_b
    while hi_b / max(lo_b, 1e-300) > 1.0 + rel_tol and calls < 64:
        mid = float(np.sqrt(lo_b * hi_b))
        ok, res = check(mid)
        calls += 1
        iters += int(res.iters)
        probes += int(res.ls_probes)
        if ok:
            hi_b, best, best_M = mid, res, mid
        else:
            lo_b = mid
    x = np.asarray(best.x)
    # covering slack is free objective: x/min(Cx) still satisfies Cx >= 1
    slack = max(float(best.min_cx), 1.0)
    x = x / slack
    obj = float(jnp.dot(c, jnp.asarray(x)))
    return BinarySearchResult(x, obj, best_M, calls, iters, probes, best)


def densest_subgraph_search(
    make_PC: Callable[[float], tuple[LinOp, LinOp]],
    lo: float,
    hi: float,
    opts: MWUOptions = MWUOptions(),
    rel_tol: float | None = None,
) -> BinarySearchResult:
    """min D s.t. the dual feasibility LP (15) is feasible.

    ``make_PC(D)`` builds (P, C) = (O/D, W). Feasible iff D >= rho*
    (the maximum density), so we search the smallest feasible D
    (eps/2 granularity; see minimize_covering).
    """
    rel_tol = opts.eps / 2 if rel_tol is None else rel_tol
    calls = iters = probes = 0

    def check(D):
        P, C = make_PC(D)
        res = solve(P, C, opts)
        return bool(res.status == Status.FEASIBLE), res

    ok, best = check(hi)
    calls += 1
    iters += int(best.iters)
    probes += int(best.ls_probes)
    if not ok:
        return BinarySearchResult(None, 0.0, hi, calls, iters, probes, best)
    lo_b, hi_b, best_D = lo, hi, hi
    while hi_b / max(lo_b, 1e-300) > 1.0 + rel_tol and calls < 64:
        mid = float(np.sqrt(lo_b * hi_b))
        ok, res = check(mid)
        calls += 1
        iters += int(res.iters)
        probes += int(res.ls_probes)
        if ok:
            hi_b, best, best_D = mid, res, mid
        else:
            lo_b = mid
    return BinarySearchResult(np.asarray(best.x), best_D, best_D, calls, iters, probes, best)
