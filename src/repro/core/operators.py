"""Linear operators for positive LPs (paper §3 + §5.1.2).

The paper's key software contribution is *implicit* representations of the
constraint matrices that arise in graph LPs:

* ``Incidence``        M  (|V| x |E|)  — matching / bmatch packing rows,
                                          transposed for vertex-cover.
* ``AdjacencyPlusId``  I+A (|V| x |V|) — dominating-set covering rows.
* ``VertexEdgePair``   O  (|V| x 2|E|) — densest-subgraph packing rows.
* ``InterweavedId``    W  (|E| x 2|E|) — densest-subgraph covering rows.

All of these are fully described by the edge list ``(u[k], v[k])`` of the
underlying graph — storing them explicitly would double (M) or quadruple
(O, W) the memory traffic. Products with the operator are segment
accumulations (scatter-add over endpoints); products with the transpose
are gathers (``w[u] + w[v]``), which is the direction the paper fuses.

TPU adaptation (DESIGN.md §3): the scatter direction lowers to XLA
scatter-add over a sorted edge list; the gather direction dispatches at
trace time through ``repro.kernels.dispatch`` — when the active
:class:`~repro.kernels.dispatch.KernelPolicy` selects the pallas
backend (``MWUOptions.kernel_backend``, resolved host-side by the solve
entry points), ``Incidence.rmatvec`` and ``VertexEdgePair.rmatvec`` run
the fused ``incidence_gather`` kernel (interpret mode on CPU CI, Mosaic
on TPU); under the default XLA policy they run the plain jnp gather
below, which doubles as the kernel's oracle. ``Transposed`` wrappers
ride along for free: vertex-cover's ``M^T`` gather is
``Transposed(Incidence).matvec`` = ``Incidence.rmatvec``.

Operators are registered pytrees, so they can be passed straight through
``jax.jit`` / ``lax.while_loop`` carries; shape metadata is static.

Conventions
-----------
* All operators are entrywise nonnegative (positive-LP requirement).
* ``matvec``:  (n,) -> (m,);  ``rmatvec``: (m,) -> (n,)  for an m x n op.
* ``colmax()`` returns per-column max entry (used for MWU's x init);
  ``colmax(row_scale)`` returns ``max_i row_scale[i] * A[i, j]`` which is
  what scaled wrappers need.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import dispatch as _kd

__all__ = [
    "LinOp",
    "Dense",
    "Coo",
    "Incidence",
    "AdjacencyPlusId",
    "VertexEdgePair",
    "InterweavedId",
    "Transposed",
    "ScaledRows",
    "OnesRow",
    "VStack",
    "register_op",
]


def register_op(cls):
    """Register a LinOp dataclass as a pytree (array fields = leaves).

    Keyed registration: leaf paths render as attribute names
    (``.P.u`` rather than ``[<flat index 0>]``), which
    ``repro.api.stack_problems`` uses to name mismatched leaves.
    """
    fields = dataclasses.fields(cls)
    leaf_names = [f.name for f in fields if not f.metadata.get("static", False)]
    static_names = [f.name for f in fields if f.metadata.get("static", False)]

    def flatten_with_keys(op):
        return (
            tuple((jax.tree_util.GetAttrKey(n), getattr(op, n)) for n in leaf_names),
            tuple(getattr(op, n) for n in static_names),
        )

    def unflatten(aux, leaves):
        kwargs = dict(zip(leaf_names, leaves))
        kwargs.update(dict(zip(static_names, aux)))
        return cls(**kwargs)

    jax.tree_util.register_pytree_with_keys(cls, flatten_with_keys, unflatten)
    return cls


def static_field(**kw):
    return dataclasses.field(metadata={"static": True}, **kw)


class LinOp:
    """Abstract nonnegative linear operator."""

    #: (rows, cols)
    shape: tuple[int, int]

    def matvec(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def rmatvec(self, y: jax.Array) -> jax.Array:
        raise NotImplementedError

    def colmax(self, row_scale: jax.Array | None = None) -> jax.Array:
        raise NotImplementedError

    # nnz as stored (implicit ops report the implicit nonzero count)
    @property
    def nnz(self) -> int:
        raise NotImplementedError

    @property
    def T(self) -> "LinOp":
        return Transposed(self)

    def materialize(self) -> jax.Array:
        """Dense (m, n) matrix — for tests/small problems only."""
        n = self.shape[1]
        return jax.vmap(self.matvec, in_axes=1, out_axes=1)(jnp.eye(n))


@register_op
@dataclass
class Dense(LinOp):
    """Explicit dense matrix (tests, tiny LPs, scipy cross-checks)."""

    mat: jax.Array

    @property
    def shape(self):
        return tuple(self.mat.shape)

    def matvec(self, x):
        return self.mat @ x

    def rmatvec(self, y):
        return self.mat.T @ y

    def colmax(self, row_scale=None):
        m = self.mat if row_scale is None else self.mat * row_scale[:, None]
        return jnp.max(m, axis=0)

    @property
    def nnz(self):
        return int(np.prod(self.mat.shape))

    def materialize(self):
        return self.mat


@register_op
@dataclass
class Coo(LinOp):
    """Padded COO: the generic explicit-sparse fallback (the "PETSc" path).

    Padding entries must carry ``val == 0`` and any in-range indices.
    """

    rows: jax.Array  # (nnz,) int32
    cols: jax.Array  # (nnz,) int32
    vals: jax.Array  # (nnz,)
    _shape: tuple[int, int] = static_field(default=(0, 0))

    @property
    def shape(self):
        return self._shape

    def matvec(self, x):
        out = jnp.zeros((self._shape[0],), dtype=x.dtype)
        return out.at[self.rows].add(self.vals.astype(x.dtype) * x[self.cols])

    def rmatvec(self, y):
        out = jnp.zeros((self._shape[1],), dtype=y.dtype)
        return out.at[self.cols].add(self.vals.astype(y.dtype) * y[self.rows])

    def colmax(self, row_scale=None):
        v = self.vals
        if row_scale is not None:
            v = v * row_scale[self.rows]
        out = jnp.zeros((self._shape[1],), dtype=v.dtype)
        return out.at[self.cols].max(v)

    @property
    def nnz(self):
        return int(self.rows.shape[0])


@register_op
@dataclass
class Incidence(LinOp):
    """Vertex-edge incidence matrix M (eq. 4): M[u, e] = 1 iff u in e.

    Stored implicitly as the edge list. Optional per-edge weights scale
    the column (both endpoints share the weight — weighted graphs).
    ``edge_mask`` zeroes padded edges (distributed layouts pad).
    """

    u: jax.Array  # (E,) int32 endpoint 0
    v: jax.Array  # (E,) int32 endpoint 1
    n_vertices: int = static_field(default=0)
    weights: Any = None  # optional (E,)
    edge_mask: Any = None  # optional (E,) bool

    @property
    def shape(self):
        return (self.n_vertices, int(self.u.shape[0]))

    def _w(self, dtype):
        E = self.u.shape[0]
        w = jnp.ones((E,), dtype) if self.weights is None else self.weights.astype(dtype)
        if self.edge_mask is not None:
            w = jnp.where(self.edge_mask, w, 0)
        return w

    def matvec(self, x):
        # y_u += x_e ; y_v += x_e  (scatter direction)
        xw = x * self._w(x.dtype)
        out = jnp.zeros((self.n_vertices,), dtype=x.dtype)
        return out.at[self.u].add(xw).at[self.v].add(xw)

    def rmatvec(self, y):
        # g_e = y_u + y_v  (gather direction — the Pallas hot spot)
        if _kd.choose("gather", y) == "pallas":
            g = _kd.gather_pallas(self.u, self.v, y)
        else:
            g = y[self.u] + y[self.v]
        return g * self._w(y.dtype)

    def colmax(self, row_scale=None):
        w = self._w(jnp.float32 if row_scale is None else row_scale.dtype)
        if row_scale is None:
            return w
        return jnp.maximum(row_scale[self.u], row_scale[self.v]) * w

    @property
    def nnz(self):
        return 2 * int(self.u.shape[0])


@register_op
@dataclass
class AdjacencyPlusId(LinOp):
    """(I + A) for dominating set (eq. 8). Symmetric; edges stored once."""

    u: jax.Array
    v: jax.Array
    n_vertices: int = static_field(default=0)
    edge_mask: Any = None

    @property
    def shape(self):
        return (self.n_vertices, self.n_vertices)

    def _mask(self, x, dtype):
        if self.edge_mask is None:
            return x
        return jnp.where(self.edge_mask, x, jnp.zeros((), dtype))

    def matvec(self, x):
        xu = self._mask(x[self.u], x.dtype)
        xv = self._mask(x[self.v], x.dtype)
        out = x  # identity part
        return out.at[self.u].add(xv).at[self.v].add(xu)

    def rmatvec(self, y):
        return self.matvec(y)  # symmetric

    def colmax(self, row_scale=None):
        if row_scale is None:
            return jnp.ones((self.n_vertices,), jnp.float32)
        # column j: entries at rows {j} ∪ N(j) -> max of row_scale there.
        out = row_scale  # identity entry
        su = self._mask(row_scale[self.u], row_scale.dtype)
        sv = self._mask(row_scale[self.v], row_scale.dtype)
        return out.at[self.u].max(sv).at[self.v].max(su)

    @property
    def nnz(self):
        return self.n_vertices + 2 * int(self.u.shape[0])


@register_op
@dataclass
class VertexEdgePair(LinOp):
    """Vertex-edge-pair matrix O (eq. 14): (|V| x 2|E|).

    Column 2e   has a 1 at row u for edge e = (u, v);
    column 2e+1 has a 1 at row v. Variables z are laid out interleaved,
    matching the paper's (13)/(14); we view z as (E, 2).
    """

    u: jax.Array
    v: jax.Array
    n_vertices: int = static_field(default=0)
    edge_mask: Any = None

    @property
    def shape(self):
        return (self.n_vertices, 2 * int(self.u.shape[0]))

    def _m(self, x, dtype):
        if self.edge_mask is None:
            return x
        return jnp.where(self.edge_mask, x, jnp.zeros((), dtype))

    def matvec(self, z):
        z2 = z.reshape(-1, 2)
        zu = self._m(z2[:, 0], z.dtype)
        zv = self._m(z2[:, 1], z.dtype)
        out = jnp.zeros((self.n_vertices,), dtype=z.dtype)
        return out.at[self.u].add(zu).at[self.v].add(zv)

    def rmatvec(self, y):
        if _kd.choose("gather", y) == "pallas":
            # Interleaved pair gather through the incidence kernel: with
            # idx = [u0, v0, u1, v1, ...], gather(idx, idx, y) = 2*y[idx]
            # and the halving is exact in binary floating point.
            idx = jnp.stack([self.u, self.v], axis=-1).reshape(-1)
            g = (0.5 * _kd.gather_pallas(idx, idx, y)).reshape(-1, 2)
        else:
            g = jnp.stack([y[self.u], y[self.v]], axis=-1)
        if self.edge_mask is not None:
            g = jnp.where(self.edge_mask[:, None], g, 0)
        return g.reshape(-1)

    def colmax(self, row_scale=None):
        E = int(self.u.shape[0])
        if row_scale is None:
            return jnp.ones((2 * E,), jnp.float32)
        return self.rmatvec(row_scale)

    @property
    def nnz(self):
        return 2 * int(self.u.shape[0])


@register_op
@dataclass
class InterweavedId(LinOp):
    """Interweaved identity W (eq. 13): (|E| x 2|E|), W[e, 2e] = W[e, 2e+1] = 1."""

    n_edges: int = static_field(default=0)
    edge_mask: Any = None

    @property
    def shape(self):
        return (self.n_edges, 2 * self.n_edges)

    def matvec(self, z):
        out = z.reshape(-1, 2).sum(axis=-1)
        if self.edge_mask is not None:
            out = jnp.where(self.edge_mask, out, 0)
        return out

    def rmatvec(self, y):
        if self.edge_mask is not None:
            y = jnp.where(self.edge_mask, y, 0)
        return jnp.repeat(y, 2, total_repeat_length=2 * self.n_edges)

    def colmax(self, row_scale=None):
        if row_scale is None:
            return jnp.ones((2 * self.n_edges,), jnp.float32)
        return self.rmatvec(row_scale)

    @property
    def nnz(self):
        return 2 * self.n_edges


@register_op
@dataclass
class Transposed(LinOp):
    """Lazy transpose wrapper (vertex cover uses M^T)."""

    inner: LinOp

    @property
    def shape(self):
        m, n = self.inner.shape
        return (n, m)

    def matvec(self, x):
        return self.inner.rmatvec(x)

    def rmatvec(self, y):
        return self.inner.matvec(y)

    def colmax(self, row_scale=None):
        # columns of A^T are rows of A: colmax_j = max_i s_i A^T[i,j]
        #                                        = max_i s_i A[j,i] -> rowmax of scaled A
        if row_scale is None:
            # max over each row of A == A @ onehot trick; use matvec with
            # (max,*) semiring replacement: for 0/1 implicit ops a row max is
            # 1 wherever the row is nonempty. Generic fallback:
            return _rowmax(self.inner, None)
        return _rowmax(self.inner, row_scale)

    @property
    def nnz(self):
        return self.inner.nnz


def _rowmax(op: LinOp, col_scale):
    """max_j op[i, j] * col_scale[j] for each row i (semiring max-product)."""
    if isinstance(op, Dense):
        m = op.mat if col_scale is None else op.mat * col_scale[None, :]
        return jnp.max(m, axis=1)
    if isinstance(op, Coo):
        v = op.vals if col_scale is None else op.vals * col_scale[op.cols]
        return jnp.zeros((op.shape[0],), v.dtype).at[op.rows].max(v)
    if isinstance(op, Incidence):
        w = op._w(jnp.float32 if col_scale is None else col_scale.dtype)
        cw = w if col_scale is None else w * col_scale
        out = jnp.zeros((op.n_vertices,), cw.dtype)
        return out.at[op.u].max(cw).at[op.v].max(cw)
    raise NotImplementedError(f"rowmax for {type(op).__name__}")


@register_op
@dataclass
class ScaledRows(LinOp):
    """diag(scale) @ inner — used to normalize b-vectors to all-ones."""

    scale: jax.Array  # (m,)
    inner: LinOp

    @property
    def shape(self):
        return self.inner.shape

    def matvec(self, x):
        return self.scale * self.inner.matvec(x)

    def rmatvec(self, y):
        return self.inner.rmatvec(self.scale * y)

    def colmax(self, row_scale=None):
        s = self.scale if row_scale is None else self.scale * row_scale
        return self.inner.colmax(s)

    @property
    def nnz(self):
        return self.inner.nnz


@register_op
@dataclass
class OnesRow(LinOp):
    """(1/M) * c^T as a single covering/packing row (objective embedding, §2.2)."""

    c: jax.Array  # (n,) nonnegative objective
    inv_bound: jax.Array  # scalar 1/M

    @property
    def shape(self):
        return (1, int(self.c.shape[0]))

    def matvec(self, x):
        return (self.inv_bound * jnp.dot(self.c, x))[None]

    def rmatvec(self, y):
        return self.inv_bound * self.c * y[0]

    def colmax(self, row_scale=None):
        s = self.inv_bound if row_scale is None else self.inv_bound * row_scale[0]
        return self.c * s

    @property
    def nnz(self):
        return int(self.c.shape[0])


@register_op
@dataclass
class VStack(LinOp):
    """Row-stack of operators sharing a column space."""

    ops: tuple  # tuple[LinOp, ...]

    @property
    def shape(self):
        return (sum(o.shape[0] for o in self.ops), self.ops[0].shape[1])

    def matvec(self, x):
        return jnp.concatenate([o.matvec(x) for o in self.ops])

    def rmatvec(self, y):
        out = None
        off = 0
        for o in self.ops:
            m = o.shape[0]
            r = o.rmatvec(jax.lax.dynamic_slice_in_dim(y, off, m))
            out = r if out is None else out + r
            off += m
        return out

    def colmax(self, row_scale=None):
        out = None
        off = 0
        for o in self.ops:
            m = o.shape[0]
            rs = None if row_scale is None else jax.lax.dynamic_slice_in_dim(row_scale, off, m)
            c = o.colmax(rs)
            out = c if out is None else jnp.maximum(out, c)
            off += m
        return out

    @property
    def nnz(self):
        return sum(o.nnz for o in self.ops)
