"""Smoothed max/min and their gradients (paper §2.2).

    smax_eta(v) = (1/eta) * log(sum_i exp(eta * v_i))
    smin_eta(v) = -(1/eta) * log(sum_i exp(-eta * v_i))

with gradients

    grad smax_eta(v) = softmax(eta * v)
    grad smin_eta(v) = softmax(-eta * v)

Everything is computed through shifted logsumexp so that no raw
``exp(eta * v)`` is ever materialized: at epsilon = 0.1 the paper's
eta = 10 log(m)/epsilon is ~100 log m, far beyond f32 (and f64) exp range.

For a masked variant (used when covering constraints are conceptually
dropped, Alg. 1 line 11) a boolean mask selects the active entries; masked
entries contribute -inf to the logsumexp.

``smax_and_weights`` / ``smin_and_weights`` — the per-iteration gradient
step of the MWU loop — dispatch through ``repro.kernels.dispatch``: under
a pallas policy the shift/exp/normalize passes run as the single fused
``softmax_weights`` kernel sweep; masked calls and the default XLA policy
take the jnp path below (which is the kernel's oracle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import dispatch as _kd

__all__ = [
    "smax",
    "smin",
    "smax_weights",
    "smin_weights",
    "smax_and_weights",
    "smin_and_weights",
    "logsumexp_shifted",
]


def logsumexp_shifted(a: jax.Array, where: jax.Array | None = None):
    """Stable logsumexp returning (lse, shift) so callers can reuse the shift.

    ``where`` masks entries out of the reduction (treated as -inf).
    """
    if where is not None:
        a = jnp.where(where, a, -jnp.inf)
    shift = jnp.max(a)
    # If everything is -inf (empty mask) keep shift finite to avoid nan.
    shift = jnp.where(jnp.isfinite(shift), shift, jnp.zeros_like(shift))
    lse = shift + jnp.log(jnp.sum(jnp.exp(a - shift)))
    return lse, shift


def smax(v: jax.Array, eta, where: jax.Array | None = None) -> jax.Array:
    """smax_eta(v); scalar. Within log(m)/eta of max(v) from above."""
    lse, _ = logsumexp_shifted(eta * v, where=where)
    return lse / eta


def smin(v: jax.Array, eta, where: jax.Array | None = None) -> jax.Array:
    """smin_eta(v); scalar. Within log(m)/eta of min(v) from below."""
    lse, _ = logsumexp_shifted(-eta * v, where=where)
    return -lse / eta


def smax_weights(v: jax.Array, eta, where: jax.Array | None = None) -> jax.Array:
    """w_p = grad smax_eta(v) = softmax(eta*v). Sums to 1."""
    a = eta * v
    if where is not None:
        a = jnp.where(where, a, -jnp.inf)
    return jax.nn.softmax(a)


def smin_weights(v: jax.Array, eta, where: jax.Array | None = None) -> jax.Array:
    """w_c = grad smin_eta(v) = softmax(-eta*v). Sums to 1."""
    a = -eta * v
    if where is not None:
        a = jnp.where(where, a, -jnp.inf)
    return jax.nn.softmax(a)


def smax_and_weights(v, eta, where=None):
    """One-pass (smax, softmax(eta v)) sharing the max-shift.

    Unmasked calls dispatch to the fused ``kernels.softmax_weights``
    Pallas sweep when the active policy selects it; the jnp path below
    is both the XLA implementation and the kernel's oracle.
    """
    if where is None and _kd.choose("softmax", v) == "pallas":
        lse, w = _kd.softmax_pallas(v, eta, sign=1.0)
        return lse / eta, w
    a = eta * v
    if where is not None:
        a = jnp.where(where, a, -jnp.inf)
    shift = jnp.max(a)
    shift = jnp.where(jnp.isfinite(shift), shift, jnp.zeros_like(shift))
    e = jnp.exp(a - shift)
    s = jnp.sum(e)
    return (shift + jnp.log(s)) / eta, e / s


def smin_and_weights(v, eta, where=None):
    """One-pass (smin, softmax(-eta v)) sharing the max-shift.

    Dispatches like :func:`smax_and_weights` (sign=-1 kernel variant).
    """
    if where is None and _kd.choose("softmax", v) == "pallas":
        lse, w = _kd.softmax_pallas(v, eta, sign=-1.0)
        return -lse / eta, w
    a = -eta * v
    if where is not None:
        a = jnp.where(where, a, -jnp.inf)
    shift = jnp.max(a)
    shift = jnp.where(jnp.isfinite(shift), shift, jnp.zeros_like(shift))
    e = jnp.exp(a - shift)
    s = jnp.sum(e)
    return -(shift + jnp.log(s)) / eta, e / s
