"""Core MWU positive-LP solver (the paper's primary contribution).

Layers: smoothing (smax/smin), operators (implicit graph LinOps),
mwu (Algorithms 1-2, one trace-unified lax.while_loop driver), stepsize
(Algorithm 3 + Newton), feasibility (deprecated binary-search shims),
gradient_descent (MPCSolver baseline), mwu_dist (2-D distributed
solver, paper §5.2).

The canonical public entry point is :mod:`repro.api` — declarative
``Problem`` specs plus the ``Solver`` facade, which drives this
module's feasibility core sequentially or vmap-batched across
binary-search bounds and graph instances. ``solve`` / ``solve_traced``
and the ``feasibility`` drivers remain for direct low-level use and
backwards compatibility.
"""
from .mwu import MWUOptions, MWUResult, Status
from .operators import (
    AdjacencyPlusId,
    Coo,
    Dense,
    Incidence,
    InterweavedId,
    LinOp,
    OnesRow,
    ScaledRows,
    Transposed,
    VertexEdgePair,
    VStack,
)
from .gradient_descent import MPCOptions, mpc_solve

# Deprecated package-level entry points, resolved lazily (PEP 562) so the
# one-per-process DeprecationWarning fires only when legacy code actually
# reaches for them — importing repro.core itself stays silent.
_DEPRECATED = {
    "solve": ("repro.core.solve", ".mwu", "repro.api.Solver.feasible"),
    "solve_traced": ("repro.core.solve_traced", ".mwu", "repro.api.Solver.feasible(trace=True)"),
    "BinarySearchResult": ("repro.core.feasibility", ".feasibility", "repro.api.Solution"),
    "maximize_packing": ("repro.core.feasibility", ".feasibility", "repro.api.Solver.solve"),
    "minimize_covering": ("repro.core.feasibility", ".feasibility", "repro.api.Solver.solve"),
    "densest_subgraph_search": ("repro.core.feasibility", ".feasibility", "repro.api.Solver.solve"),
    "mwu_dist": ("core.mwu_dist", ".mwu_dist", "repro.dist.DistSolver"),
}


def __getattr__(name):
    entry = _DEPRECATED.get(name)
    if entry is None:
        raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
    import importlib

    from ..utils.deprecation import warn_once

    key, module, replacement = entry
    warn_once(key, f"{key} is deprecated; use {replacement}")
    mod = importlib.import_module(module, __name__)
    return mod if name == "mwu_dist" else getattr(mod, name)

__all__ = [
    "MWUOptions",
    "MWUResult",
    "Status",
    "solve",
    "solve_traced",
    "LinOp",
    "Dense",
    "Coo",
    "Incidence",
    "AdjacencyPlusId",
    "VertexEdgePair",
    "InterweavedId",
    "Transposed",
    "ScaledRows",
    "OnesRow",
    "VStack",
    "BinarySearchResult",
    "maximize_packing",
    "minimize_covering",
    "densest_subgraph_search",
    "MPCOptions",
    "mpc_solve",
]
