"""Core MWU positive-LP solver (the paper's primary contribution).

Layers: smoothing (smax/smin), operators (implicit graph LinOps),
mwu (Algorithms 1-2, one trace-unified lax.while_loop driver), stepsize
(Algorithm 3 + Newton), feasibility (deprecated binary-search shims),
gradient_descent (MPCSolver baseline), mwu_dist (2-D distributed
solver, paper §5.2).

The canonical public entry point is :mod:`repro.api` — declarative
``Problem`` specs plus the ``Solver`` facade, which drives this
module's feasibility core sequentially or vmap-batched across
binary-search bounds and graph instances. ``solve`` / ``solve_traced``
and the ``feasibility`` drivers remain for direct low-level use and
backwards compatibility.
"""
from .mwu import MWUOptions, MWUResult, Status, solve, solve_traced
from .operators import (
    AdjacencyPlusId,
    Coo,
    Dense,
    Incidence,
    InterweavedId,
    LinOp,
    OnesRow,
    ScaledRows,
    Transposed,
    VertexEdgePair,
    VStack,
)
from .feasibility import (
    BinarySearchResult,
    densest_subgraph_search,
    maximize_packing,
    minimize_covering,
)
from .gradient_descent import MPCOptions, mpc_solve

__all__ = [
    "MWUOptions",
    "MWUResult",
    "Status",
    "solve",
    "solve_traced",
    "LinOp",
    "Dense",
    "Coo",
    "Incidence",
    "AdjacencyPlusId",
    "VertexEdgePair",
    "InterweavedId",
    "Transposed",
    "ScaledRows",
    "OnesRow",
    "VStack",
    "BinarySearchResult",
    "maximize_packing",
    "minimize_covering",
    "densest_subgraph_search",
    "MPCOptions",
    "mpc_solve",
]
