"""Core MWU positive-LP solver (the paper's primary contribution).

Layers: smoothing (smax/smin), operators (implicit graph LinOps),
mwu (Algorithms 1-2), stepsize (Algorithm 3 + Newton), feasibility
(optimization via binary search), gradient_descent (MPCSolver baseline),
mwu_dist (2-D distributed solver, paper §5.2).
"""
from .mwu import MWUOptions, MWUResult, Status, solve, solve_traced
from .operators import (
    AdjacencyPlusId,
    Coo,
    Dense,
    Incidence,
    InterweavedId,
    LinOp,
    OnesRow,
    ScaledRows,
    Transposed,
    VertexEdgePair,
    VStack,
)
from .feasibility import (
    BinarySearchResult,
    densest_subgraph_search,
    maximize_packing,
    minimize_covering,
)
from .gradient_descent import MPCOptions, mpc_solve

__all__ = [
    "MWUOptions",
    "MWUResult",
    "Status",
    "solve",
    "solve_traced",
    "LinOp",
    "Dense",
    "Coo",
    "Incidence",
    "AdjacencyPlusId",
    "VertexEdgePair",
    "InterweavedId",
    "Transposed",
    "ScaledRows",
    "OnesRow",
    "VStack",
    "BinarySearchResult",
    "maximize_packing",
    "minimize_covering",
    "densest_subgraph_search",
    "MPCOptions",
    "mpc_solve",
]
