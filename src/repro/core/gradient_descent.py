"""MPCSolver baseline: stateless gradient descent with adaptive error.

Reimplementation of the comparison algorithm of Makari et al. [31]
(based on Awerbuch & Khandekar's stateless distributed gradient descent
[7]), as described in the paper's Appendix A.3, for the Figure-3
convergence study. It minimizes

    Gamma(x) = sum_i exp(mu (P_i x - 1)) + sum_i exp(mu (1 - C_i x))

by multiplicative coordinate updates: coordinates whose covering pull
exceeds their packing pull (C^T z vs P^T y) are scaled up, the opposite
scaled down. The *adaptive error* strategy starts with a coarse internal
tolerance eps' >> eps (mu ~ log(m)/eps' small => big moves) and tightens
eps' whenever progress stagnates, warm-starting from the current x.

Exact constants in [31] are tuned per-problem; we follow the published
structure (mu = ln(3m/eps')/eps', multiplicative step beta = eps'/8,
stagnation window + halving) and note this is a faithful *shape*
reproduction used for iteration-count comparison, as the paper itself
compares iteration counts, not wall time, against this method.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .operators import LinOp

__all__ = ["MPCOptions", "mpc_solve"]


@dataclass(frozen=True)
class MPCOptions:
    eps: float = 0.05  # target relative error (Makari et al. use 0.05)
    eps_internal0: float = 1.0  # initial adaptive internal error
    max_iter: int = 20000
    stagnation_window: int = 50
    stagnation_rtol: float = 1e-3
    beta_factor: float = 0.125  # beta = beta_factor * eps'


@partial(jax.jit, static_argnames=("has_mask",))
def _mpc_iter(P: LinOp, C: LinOp, x, mu, beta, x_max, c_mask, has_mask):
    y = jnp.exp(jnp.clip(mu * (P.matvec(x) - 1.0), -60.0, 60.0))
    zc = C.matvec(x)
    z = jnp.exp(jnp.clip(mu * (1.0 - zc), -60.0, 60.0))
    if has_mask:
        z = jnp.where(c_mask, z, 0.0)
    gp = P.rmatvec(y)  # packing push (wants x smaller)
    gc = C.rmatvec(z)  # covering pull (wants x larger)
    up = gc > (1.0 + beta) * gp
    dn = gp > (1.0 + beta) * gc
    fac = jnp.where(up, 1.0 + beta, jnp.where(dn, 1.0 - beta, 1.0))
    x2 = jnp.clip(x * fac, 1e-30, x_max)
    z2 = C.matvec(x2)
    min_c = jnp.min(jnp.where(c_mask, z2, jnp.inf)) if has_mask else jnp.min(z2)
    viol = jnp.maximum(
        0.0, jnp.maximum(jnp.max(P.matvec(x2)) - 1.0, 1.0 - min_c)
    )
    return x2, viol


def mpc_solve(P: LinOp, C: LinOp, opts: MPCOptions = MPCOptions(), c_mask=None):
    """Run MPCSolver; returns (x, trace dict) with per-iteration violation."""
    m = P.shape[0] + C.shape[0]
    n = P.shape[1]
    dt = jnp.result_type(float)  # canonical float: f64 iff x64 is enabled

    # start tiny like MWU so packing starts satisfied
    cm = P.colmax().astype(dt)
    safe = jnp.where(cm > 0, cm, 1.0)
    x = (opts.eps / (n * safe)).astype(dt)
    x_max = jnp.asarray(float(n), dt)  # generous cap

    has_mask = c_mask is not None
    cm = c_mask if has_mask else jnp.zeros((C.shape[0],), bool)
    eps_i = opts.eps_internal0
    viols = []
    it = 0
    best_recent = np.inf
    window_count = 0
    while it < opts.max_iter:
        mu = jnp.asarray(np.log(3 * m / opts.eps) / eps_i, dt)
        beta = jnp.asarray(opts.beta_factor * eps_i, dt)
        x, viol = _mpc_iter(P, C, x, mu, beta, x_max, cm, has_mask)
        v = float(viol)
        viols.append(v)
        it += 1
        if v <= opts.eps:
            break
        # adaptive error: tighten eps' when stagnating (Appendix A.3)
        if v < best_recent * (1.0 - opts.stagnation_rtol):
            best_recent = v
            window_count = 0
        else:
            window_count += 1
            if window_count >= opts.stagnation_window:
                eps_i = max(eps_i / 2.0, opts.eps)
                best_recent = np.inf
                window_count = 0
    return np.asarray(x), {"max_violation": np.asarray(viols), "iters": it}
