"""Host-side mesh planning for the distributed solver layer.

A :class:`MeshPlan` is to device meshes what
:class:`repro.kernels.dispatch.KernelPolicy` is to kernel backends: a
frozen, hashable description resolved OUTSIDE ``jax.jit`` and passed
around as a static argument, so the jit/shard_map callable cache is
keyed on the concrete mesh shape and can never serve a plan built for a
different device set.

Two axes (paper §5 mapped onto SPMD):

* ``data`` — the embarrassingly-parallel fan-out axis: independent
  feasibility lanes (binary-search bounds, stacked graph instances,
  lpserve lane slots) shard here with zero cross-device communication,
  exactly the MPI rank-level parallelism of the paper's bound sweep.
* ``pod``  — the within-solve axis: one LP's *variable space* is
  slab-partitioned here (:mod:`repro.dist.shard`), with the smax/smin
  coupling completed by per-iteration ``psum``s — the paper's
  edge-partitioned OpenMP+MPI scheme, with the psum standing in for its
  neighbor exchange.

``MeshPlan.build`` constructs the actual ``jax.sharding.Mesh`` over the
first ``pod * data`` host devices (via :func:`repro.launch.mesh.make_mesh`),
and :meth:`MeshPlan.shard_map` wraps :func:`repro.utils.compat.shard_map`
so version-dependent kwargs (``check_vma``/``check_rep``) are threaded in
one place.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from ..launch.mesh import make_mesh
from ..utils import compat

__all__ = ["MeshPlan", "POD_AXIS", "DATA_AXIS"]

POD_AXIS = "pod"
DATA_AXIS = "data"

# one Mesh per plan per process: Mesh construction touches device state,
# and shard_map callables close over the mesh, so identity stability
# keeps the downstream jit caches warm.
_MESH_CACHE: dict["MeshPlan", object] = {}


@dataclass(frozen=True)
class MeshPlan:
    """A hashable (pod, data) mesh request, resolved host-side.

    ``pod`` devices cooperate on each solve (variable-space slabs +
    psum); ``data`` groups run independent lanes. ``MeshPlan()`` is the
    1-device identity plan — the distributed driver run under it is
    bit-identical to the single-device ``Solver`` path.
    """

    pod: int = 1
    data: int = 1

    def __post_init__(self):
        if self.pod < 1 or self.data < 1:
            raise ValueError(f"MeshPlan axes must be >= 1, got pod={self.pod} data={self.data}")

    @property
    def n_devices(self) -> int:
        return self.pod * self.data

    @property
    def axes(self) -> tuple[str, str]:
        return (POD_AXIS, DATA_AXIS)

    def build(self):
        """The concrete ``Mesh`` over the first ``pod * data`` devices."""
        mesh = _MESH_CACHE.get(self)
        if mesh is not None:
            return mesh
        devices = jax.devices()
        if len(devices) < self.n_devices:
            raise ValueError(
                f"MeshPlan(pod={self.pod}, data={self.data}) needs "
                f"{self.n_devices} devices but only {len(devices)} are "
                "visible (on CPU, set XLA_FLAGS="
                "--xla_force_host_platform_device_count=N before importing jax)"
            )
        mesh = make_mesh((self.pod, self.data), self.axes, devices=devices[: self.n_devices])
        _MESH_CACHE[self] = mesh
        return mesh

    def shard_map(self, f, *, in_specs, out_specs, check_vma: bool = False):
        """``compat.shard_map`` over this plan's mesh.

        ``check_vma`` defaults off: the solver's replication invariants
        (constraint-space vectors re-replicate through the operator
        psums) are not expressible to the static rep checker — they are
        asserted numerically by ``tests/test_dist_solver.py`` instead.
        """
        return compat.shard_map(
            f, mesh=self.build(), in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
