"""repro.dist — mesh-sharded distributed solver layer (paper §5).

The distributed translation of the paper's MPI scheme onto jax SPMD:

* :class:`MeshPlan` — host-side (pod, data) mesh description, resolved
  into a concrete device mesh exactly like ``kernels.dispatch`` resolves
  a ``KernelPolicy`` (frozen, hashable, jit-cache-safe).
* :mod:`repro.dist.shard` — ``PartitionSpec`` layouts for ``Problem``
  pytrees plus the :class:`PodSum` / :class:`SlabCols` operator wrappers
  that psum-complete the constraint-space coupling.
* :class:`DistSolver` — ``repro.api.Solver`` with its feasibility
  primitives wrapped in ``shard_map``; bit-identical on ``MeshPlan()``,
  edge-slab-parallel on pod-sharded plans.

``repro.lpserve`` accepts a ``MeshPlan`` in its config to shard lane
slots across the mesh; ``core.mwu_dist`` is the deprecated predecessor
kept as a shim over this package.
"""
from .mesh import DATA_AXIS, POD_AXIS, MeshPlan
from .shard import PodSum, SlabCols, pod_mode, problem_specs, slab_pad_problem
from .solver import DistSolver

__all__ = [
    "MeshPlan",
    "POD_AXIS",
    "DATA_AXIS",
    "DistSolver",
    "PodSum",
    "SlabCols",
    "pod_mode",
    "problem_specs",
    "slab_pad_problem",
]
