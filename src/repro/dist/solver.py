"""``DistSolver``: the core MWU while_loop under ``shard_map``.

The single-device :class:`repro.api.Solver` already vmaps the jitted
``lax.while_loop`` across bounds and stacked instances. This module
wraps that exact driver — same ``core.mwu._run``, same kernel dispatch,
same options — in a ``shard_map`` over a :class:`~repro.dist.mesh.MeshPlan`:

* lanes (bounds x instances) slab across ``data`` — zero communication,
  the paper's rank-level bound sweep;
* each lane's variable space slabs across ``pod`` (``repro.dist.shard``),
  with the constraint-space coupling psum-completed per matvec — the
  paper's edge-partitioned within-solve scheme.

Two execution shapes, chosen host-side:

* **vmap path** (the default, and ALWAYS on a 1-device plan): the body
  vmaps lanes exactly like ``Solver.solve_batch``. On ``MeshPlan(1, 1)``
  every collective is a singleton identity and no slab padding is
  inserted, so results are bit-identical to the undistributed solver —
  the parity contract ``tests/test_dist_solver.py`` pins down.
* **no-vmap fast path** (multi-device plans with one lane per data
  group): the body runs the loop unbatched. This matters because the
  Pallas entry points are ``custom_vmap``-wrapped with XLA batch rules —
  only the unbatched body keeps the fused kernel pack on the hot path,
  so a pure-pod plan accelerates single solves without giving up the
  kernels.

``DistSolver`` subclasses ``Solver`` and overrides only the two
feasibility primitives; the inherited bound-search driver (``solve``)
is thereby distributed for free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..api.solver import Solver
from ..core.mwu import _run
from ..kernels import dispatch as _kd
from ..sparsela.partition import partition_edges_1d
from .mesh import POD_AXIS, MeshPlan
from .shard import (
    PodSum,
    SlabCols,
    bounds_spec,
    global_columns,
    pod_mode,
    problem_specs,
    result_specs,
    slab_pad_problem,
)

__all__ = ["DistSolver"]

# jitted shard_map callables keyed on everything static about a launch;
# rebuilding the closure per call would retrace/recompile every time.
_CALLABLE_CACHE: dict = {}


def _build_callable(plan: MeshPlan, opts, kernels, mode, ncols, block, batched, no_vmap, specs):
    """One jitted shard_map program for a (plan, problem-shape) combo."""

    # pod == 1: the wrappers and collectives are mathematical identities,
    # but they still change the emitted HLO enough to perturb XLA fusion
    # rounding — skip them so the traced body is op-for-op the same as
    # ``Solver.solve_batch``'s and 1-device results stay BIT-identical.
    pod_sharded = plan.pod > 1

    def wrap(op):
        if not pod_sharded:
            return op
        if mode == "edge_slab":
            return PodSum(op)
        return SlabCols(op, block=block, n_pod=plan.pod, n_cols=ncols)

    axis = POD_AXIS if pod_sharded else None
    init_cols = ncols if pod_sharded else None

    def one(p, b):
        P, C, pm, cm = p.instantiate(b)
        return _run(
            wrap(P), wrap(C), opts, pm, cm, kernels=kernels, axis=axis, init_cols=init_cols
        )

    if no_vmap:
        # one lane per data group: run the loop unbatched so the Pallas
        # custom_vmap entry points stay on their kernel (not XLA-ref) path.
        def body(problem, bounds):
            p = jax.tree.map(lambda a: a[0], problem) if batched else problem
            res = one(p, bounds[0])
            return jax.tree.map(lambda a: a[None], res)

    else:

        def body(problem, bounds):
            return jax.vmap(one, in_axes=(0 if batched else None, 0))(problem, bounds)

    sharded = plan.shard_map(body, in_specs=(specs, bounds_spec()), out_specs=result_specs())
    return jax.jit(sharded)


class DistSolver(Solver):
    """Mesh-sharded drop-in for :class:`repro.api.Solver`.

    Parameters are ``Solver``'s plus ``plan``, the
    :class:`~repro.dist.mesh.MeshPlan` to launch on.  ``MeshPlan()`` (the
    default) is the 1-device identity plan: every result is bit-identical
    to the plain ``Solver``, so callers can hold a single solver type and
    scale by swapping the plan.

    ``dist_stats`` counts launches / lanes / MWU iterations and (for
    pod-sharded plans) an estimate of psum rounds — 3 collectives per
    iteration (dy, dz, pmax) plus init (y, z, pmin) — surfaced by
    ``repro.lpserve``'s ``stats()``.
    """

    def __init__(self, opts=None, *, plan: MeshPlan | None = None, **kwargs):
        super().__init__(opts, **kwargs)
        self.plan = plan if plan is not None else MeshPlan()
        self.dist_stats = {
            "launches": 0,
            "feasibility_calls": 0,
            "mwu_iters": 0,
            "psum_rounds": 0,
        }

    # -- feasibility primitives (everything else is inherited) ---------
    def _prepare_launch(self, problem, bounds, batched_problem: bool) -> dict:
        """Host-side launch prep shared by execution and AOT inspection.

        Everything up to (but excluding) running the jitted shard_map
        program: pod-mode detection, slab/lane padding, the no-vmap
        decision, kernel-policy resolution, and the callable-cache
        lookup. Returns the padded operands plus the cached callable and
        the static facts (mode, ncols, B) the caller needs afterwards.
        """
        plan = self.plan
        bounds = jnp.atleast_1d(jnp.asarray(bounds))
        B = int(bounds.shape[0])
        mode = pod_mode(problem)

        if mode == "edge_slab":
            problem, ncols = slab_pad_problem(problem, plan.pod)
            _, block = partition_edges_1d(ncols, plan.pod)
        else:
            ncols = global_columns(problem, np.asarray(bounds)[0], batched_problem)
            block = -(-ncols // plan.pod)

        pad = (-B) % plan.data
        if pad:
            bounds = jnp.concatenate([bounds, jnp.broadcast_to(bounds[-1:], (pad,))])
            if batched_problem:
                problem = jax.tree.map(
                    lambda a: jnp.concatenate(
                        [
                            jnp.asarray(a),
                            jnp.broadcast_to(
                                jnp.asarray(a)[-1:], (pad,) + tuple(jnp.shape(a)[1:])
                            ),
                        ]
                    ),
                    problem,
                )
        no_vmap = plan.n_devices > 1 and B + pad == plan.data

        kernels = _kd.resolve(self.opts.kernel_backend)  # host-side, pre-jit
        specs = problem_specs(problem, mode, batched_problem)
        key = (
            plan,
            self.opts,
            kernels,
            mode,
            ncols,
            block,
            batched_problem,
            no_vmap,
            jax.tree_util.tree_structure(problem),
        )
        fn = _CALLABLE_CACHE.get(key)
        if fn is None:
            fn = _build_callable(
                plan, self.opts, kernels, mode, ncols, block, batched_problem, no_vmap, specs
            )
            _CALLABLE_CACHE[key] = fn
        return {
            "problem": problem,
            "bounds": bounds,
            "fn": fn,
            "mode": mode,
            "ncols": ncols,
            "B": B,
            "no_vmap": no_vmap,
        }

    # -- AOT inspection hooks (repro.tracecheck) -----------------------
    def lower_batch(self, problem, bounds, *, batched_problem: bool = False):
        """AOT-lower the mesh-sharded launch this ``solve_batch`` would run."""
        launch = self._prepare_launch(problem, bounds, batched_problem)
        return launch["fn"].lower(launch["problem"], launch["bounds"])

    def jaxpr_batch(self, problem, bounds, *, batched_problem: bool = False):
        """ClosedJaxpr of the mesh-sharded launch (shard_map body visible)."""
        launch = self._prepare_launch(problem, bounds, batched_problem)
        return jax.make_jaxpr(launch["fn"])(launch["problem"], launch["bounds"])

    def solve_batch(self, problem, bounds, *, batched_problem: bool = False):
        """Batched feasibility fanned out over the (pod, data) mesh.

        Same contract as ``Solver.solve_batch``: returns an ``MWUResult``
        with leading dim ``len(bounds)``. Lanes shard over ``data`` (the
        lane count is padded host-side to a multiple of the axis by
        repeating the last lane; padding is stripped before returning),
        each lane's variable space shards over ``pod``.
        """
        launch = self._prepare_launch(problem, bounds, batched_problem)
        problem, bounds, fn = launch["problem"], launch["bounds"], launch["fn"]
        plan, B, ncols = self.plan, launch["B"], launch["ncols"]

        res = fn(problem, bounds)
        res = jax.tree.map(lambda a: a[:B], res)
        res = res._replace(x=res.x[:, :ncols])

        iters = np.asarray(res.iters)
        self.dist_stats["launches"] += 1
        self.dist_stats["feasibility_calls"] += B
        self.dist_stats["mwu_iters"] += int(iters.sum())
        if plan.pod > 1:
            self.dist_stats["psum_rounds"] += 3 * int(iters.max(initial=0)) + 3
        return res

    def feasible(self, problem, bound=None, trace: bool = False):
        """One feasibility solve, pod-sharded when the plan is multi-device.

        Tracing (``trace=True``) stays on the single-device path: the
        io_callback hook is host-side and per-process, so it does not
        compose with shard_map. On a 1-device plan the inherited path is
        also the bit-parity baseline, so it is used directly.
        """
        if trace or self.plan.n_devices == 1:
            return super().feasible(problem, bound, trace=trace)
        b = 1.0 if bound is None else float(bound)
        batch = self.solve_batch(problem, [b])
        return jax.tree.map(lambda a: a[0], batch)
