"""Sharding layout for ``Problem`` pytrees on a (pod, data) mesh.

PAPER.md's MPI scheme partitions the incidence matrix by edges: each
rank owns an edge slab, runs the gather/scatter kernels on its slab,
and exchanges the vertex-space coupling terms (the smax/smin gradient
weights live in vertex space) with its neighbors. This module is the
SPMD translation of that layout:

* **edge_slab mode** — the paper's scheme, verbatim. For packing
  problems whose operator is an :class:`~repro.core.operators.Incidence`
  with an objective-covering row (matching / b-matching — the paper's
  flagship distributed workload), the edge-dimension leaves
  (``P.u``, ``P.v``, ``P.weights``, ``P.edge_mask``, ``c``) shard
  across ``pod`` via :func:`repro.sparsela.partition.partition_edges_1d`.
  Each device runs the fused Pallas kernel pack on its local edge slab;
  the per-iteration vertex images ``y = Px`` / ``dy = Pd`` and the
  objective row ``z = <c,x>/M`` are completed by one ``psum`` each
  (:class:`PodSum`) — the psum plays the role of the paper's neighbor
  exchange, and constraint-space vectors stay replicated so the
  smoothing / line-search math is untouched.

* **column mode** — the generic fallback for every other family
  (vertex cover, dominating set, densest subgraph, generalized
  matching). The operator leaves stay replicated; :class:`SlabCols`
  views a contiguous *column* (variable) slab as the local operator by
  embedding the slab into the full column space for ``matvec`` (then
  psum) and extracting the slab from full-width ``rmatvec``/``colmax``
  results. Correct SPMD semantics on any operator zoo member — but no
  per-device work reduction; it exists so ``DistSolver`` is total over
  the Problem surface, and so the ``data``-axis fan-out (which IS a
  real speedup for every family) composes with a nontrivial pod axis.

Replication invariant (what makes the core driver reusable): every
constraint-row vector (y, z, dy, dz, the masks, every line-search
probe) is replicated across ``pod`` because the wrapped ``matvec``
psums; only two *variable-space* reductions in the whole MWU loop need
axis-awareness (``init_x``'s fallback min, the infeasible-direction
``max(d)``), which ``core.mwu._run`` handles via its ``axis`` argument.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec

from ..api.problem import Problem
from ..core.operators import Incidence, LinOp, register_op, static_field
from ..sparsela.partition import partition_edges_1d
from .mesh import DATA_AXIS, POD_AXIS

__all__ = [
    "PodSum",
    "SlabCols",
    "pod_mode",
    "slab_pad_problem",
    "problem_specs",
    "bounds_spec",
    "result_specs",
    "global_columns",
]


# ------------------------------------------------------------- operators --
@register_op
@dataclass
class PodSum(LinOp):
    """Edge-slab wrapper: local scatter, psum-completed constraint rows.

    ``inner`` is built from this device's edge slab but keeps *global*
    vertex ids (rows). ``matvec`` therefore produces a partial
    constraint image which one ``psum`` over ``axis`` completes — after
    which y/z are fully replicated, so ``rmatvec`` (gather of a
    replicated vector onto the local slab) and ``colmax`` (per-local-
    column) need no communication at all. This is the paper's
    edge-partitioned SpMV pair with psum as the exchange.
    """

    inner: LinOp
    axis: str = static_field(default=POD_AXIS)

    @property
    def shape(self):
        return self.inner.shape

    def matvec(self, x):
        return lax.psum(self.inner.matvec(x), self.axis)

    def rmatvec(self, y):
        return self.inner.rmatvec(y)

    def colmax(self, row_scale=None):
        return self.inner.colmax(row_scale)

    @property
    def nnz(self):
        return self.inner.nnz


@register_op
@dataclass
class SlabCols(LinOp):
    """Column-slab view of a replicated operator (generic pod fallback).

    Device k owns columns ``[k * block, (k + 1) * block)`` of the
    ``n_cols``-wide ``inner`` (whose leaves are replicated across the
    axis). ``matvec`` embeds the local slab into a zero-padded full
    vector, applies ``inner`` and psums the linear partials;
    ``rmatvec``/``colmax`` compute full-width and extract the slab.
    Semantically exact for any linear operator; the per-device matvec
    work is NOT reduced (see module docstring for why it exists).
    """

    inner: LinOp
    block: int = static_field(default=0)  # local slab width
    n_pod: int = static_field(default=1)  # devices on the axis
    n_cols: int = static_field(default=0)  # true global column count
    axis: str = static_field(default=POD_AXIS)

    @property
    def shape(self):
        return (self.inner.shape[0], self.block)

    def _embed(self, x):
        """Local slab -> full (n_cols,) vector, zeros elsewhere."""
        buf = jnp.zeros((self.block * self.n_pod,), x.dtype)
        start = lax.axis_index(self.axis) * self.block
        buf = lax.dynamic_update_slice(buf, x, (start,))
        return buf[: self.n_cols]

    def _extract(self, full):
        """Full (n_cols,) vector -> this device's slab (zero past the end)."""
        pad = self.block * self.n_pod - self.n_cols
        fullp = jnp.pad(full, (0, pad))
        start = lax.axis_index(self.axis) * self.block
        return lax.dynamic_slice(fullp, (start,), (self.block,))

    def matvec(self, x):
        return lax.psum(self.inner.matvec(self._embed(x)), self.axis)

    def rmatvec(self, y):
        return self._extract(self.inner.rmatvec(y))

    def colmax(self, row_scale=None):
        return self._extract(self.inner.colmax(row_scale))

    @property
    def nnz(self):
        return self.inner.nnz


# ----------------------------------------------------------- mode choice --
def pod_mode(problem: Problem) -> str:
    """``"edge_slab"`` when the paper's edge partition applies, else ``"column"``.

    Edge-slab needs the variables to BE the edges of an ``Incidence``
    packing operator with the objective entering as a covering row
    (``bound_mode="objective_covering"``): then ``P.u/v/weights/
    edge_mask`` and ``c`` are all edge-aligned and slab-shardable.
    """
    P = problem.P
    if (
        problem.bound_mode == "objective_covering"
        and isinstance(P, Incidence)
        and problem.c is not None
        and int(jnp.shape(problem.c)[-1]) == int(jnp.shape(P.u)[-1])
    ):
        return "edge_slab"
    return "column"


# ---------------------------------------------------------- slab padding --
def _pad_last(a, pad: int, fill):
    if a is None or pad == 0:
        return a
    a = jnp.asarray(a)
    widths = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
    return jnp.pad(a, widths, constant_values=fill)


def slab_pad_problem(problem: Problem, pod: int) -> tuple[Problem, int]:
    """Pad the edge dimension to a multiple of ``pod`` (edge_slab mode).

    Padded edges are fully masked (``edge_mask=False``, zero objective),
    appended at the global end so contiguous pod slabs reassemble into
    padded-global order and ``x[..., :n_edges]`` strips them. Returns
    ``(padded problem, original edge count)``; with ``pod == 1`` the
    problem is returned untouched (bit-parity with the vmap path).
    """
    P = problem.P
    n_edges = int(jnp.shape(P.u)[-1])
    padded, _ = partition_edges_1d(n_edges, pod)
    pad = padded - n_edges
    if pad == 0:
        return problem, n_edges
    mask = P.edge_mask
    if mask is None:
        mask = jnp.ones(jnp.shape(P.u), bool)
    P2 = Incidence(
        u=_pad_last(P.u, pad, 0),
        v=_pad_last(P.v, pad, 0),
        n_vertices=P.n_vertices,
        weights=_pad_last(P.weights, pad, 0),
        edge_mask=_pad_last(mask, pad, False),
    )
    c2 = _pad_last(problem.c, pad, 0)
    return dataclasses.replace(problem, P=P2, c=c2), n_edges


# -------------------------------------------------------------- specs ----
# Leaf paths (attribute-name tuples) that carry the edge dimension in
# edge_slab mode; everything else is replicated across pod.
_EDGE_LEAF_PATHS = {
    ("P", "u"),
    ("P", "v"),
    ("P", "weights"),
    ("P", "edge_mask"),
    ("c",),
}


def problem_specs(problem: Problem, mode: str, batched: bool):
    """PartitionSpec pytree for a ``Problem`` under the (pod, data) mesh.

    Batched problems (``stack_problems`` output) shard their leading
    instance axis over ``data``; in edge_slab mode the trailing edge
    axis of the edge-aligned leaves additionally shards over ``pod``.
    Every other leaf is replicated (constraint-space masks, bounds,
    column-mode operators). The result feeds ``shard_map`` in_specs and,
    via :func:`repro.launch.mesh.sharding_for`, explicit device_puts.
    """
    lead = (DATA_AXIS,) if batched else ()

    def one(path, leaf):
        names = tuple(k.name for k in path if isinstance(k, jax.tree_util.GetAttrKey))
        if mode == "edge_slab" and names in _EDGE_LEAF_PATHS:
            return PartitionSpec(*lead, POD_AXIS)
        return PartitionSpec(*lead)

    return jax.tree_util.tree_map_with_path(one, problem)


def bounds_spec() -> PartitionSpec:
    """Bounds fan out over the data axis (one lane group per data row)."""
    return PartitionSpec(DATA_AXIS)


def result_specs():
    """out_specs for a batched ``MWUResult``: x carries the pod slabs."""
    from ..core.mwu import MWUResult

    return MWUResult(
        x=PartitionSpec(DATA_AXIS, POD_AXIS),
        status=PartitionSpec(DATA_AXIS),
        iters=PartitionSpec(DATA_AXIS),
        ls_probes=PartitionSpec(DATA_AXIS),
        max_px=PartitionSpec(DATA_AXIS),
        min_cx=PartitionSpec(DATA_AXIS),
    )


# ---------------------------------------------------------- column count --
def global_columns(problem: Problem, bound, batched: bool) -> int:
    """Host-side global variable count of the instantiated feasibility LP.

    This is the ``n`` the single-device ``init_x`` would see — the
    distributed driver passes it through ``_run(init_cols=...)`` so the
    init scale (and hence the whole trajectory) matches the unsharded
    solve regardless of slab padding.
    """
    template = problem
    if batched:
        template = jax.tree.map(lambda a: jnp.asarray(a)[0], problem)
    P0, C0, _, _ = template.instantiate(None if problem.bound_mode == "none" else float(bound))
    ref = P0 if P0 is not None else C0
    return int(ref.shape[1])
