"""Graph problems as declarative positive LPs (paper §3).

Each builder returns a :class:`repro.api.Problem` bundling the implicit
operators, the objective, binary-search bounds derived from
combinatorial heuristics (graphs/baselines.py), and the static metadata
(sense, bound mode) the unified :class:`repro.api.Solver` needs. The
builders are pure — no closures, no solver state — so Problems can be
tree-stacked and vmapped across instances.

| problem    | LP                                   | type          |
|------------|--------------------------------------|---------------|
| match      | max 1.x : M x <= 1                   | pure packing  |
| bmatch     | same, bipartite input                | pure packing  |
| vcover     | min 1.x : M^T x >= 1                 | pure covering |
| dom-set    | min 1.x : (I+A) x >= 1               | pure covering |
| dense-sub  | min D : W z >= 1, O z <= D 1         | mixed, D-search |
| gen-match  | exists x: lb <= M x <= ub, x <= 1    | mixed feasibility |

``ProblemLP`` is a deprecated alias of ``Problem``: ``ProblemLP.solve``
IS the new path (``Solver().solve``).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..api import Problem
from ..core import (
    AdjacencyPlusId,
    Coo,
    Incidence,
    InterweavedId,
    ScaledRows,
    Transposed,
    VertexEdgePair,
    VStack,
)
from . import baselines
from .graph import Graph

__all__ = ["ProblemLP", "matching_lp", "bmatching_lp", "vcover_lp", "domset_lp",
           "densest_subgraph_lp", "generalized_matching_lp",
           "generalized_matching_problem", "build", "PROBLEMS"]

# Deprecated alias: the old ProblemLP closure bundle is gone; builders
# return declarative repro.api.Problem specs and .solve delegates to the
# unified Solver facade. Lazy (PEP 562) so the one-per-process
# DeprecationWarning fires only on actual use.
def __getattr__(name):
    if name == "ProblemLP":
        from ..utils.deprecation import warn_once

        warn_once("ProblemLP", "ProblemLP is deprecated; use repro.api.Problem")
        return Problem
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def matching_lp(g: Graph, name="match") -> Problem:
    """max <1,x> : Mx <= 1 (eq. 6). Bounds via greedy maximal matching:
    greedy g_m has nu_int <= 2 g_m, and LP <= 3/2 nu_int <= 3 g_m."""
    P = Incidence(u=jnp.asarray(g.u), v=jnp.asarray(g.v), n_vertices=g.n)
    gm = max(baselines.greedy_maximal_matching(g), 1)
    lo, hi = float(gm), float(min(3.0 * gm, g.n / 2.0) + 1.0)
    return Problem(
        name=name, kind="packing", sense="max", bound_mode="objective_covering",
        P=P, c=jnp.ones((g.m,)), lo=lo, hi=hi, n_vars=g.m, nnz=P.nnz, graph=g,
    )


def bmatching_lp(g: Graph) -> Problem:
    """Bipartite matching: LP is integral (no gap); bounds [g_m, 2 g_m]."""
    assert g.bipartite_split is not None, "bmatch requires a bipartite graph"
    P = Incidence(u=jnp.asarray(g.u), v=jnp.asarray(g.v), n_vertices=g.n)
    gm = max(baselines.greedy_maximal_matching(g), 1)
    lo, hi = float(gm), float(2.0 * gm + 1.0)
    return Problem(
        name="bmatch", kind="packing", sense="max", bound_mode="objective_covering",
        P=P, c=jnp.ones((g.m,)), lo=lo, hi=hi, n_vars=g.m, nnz=P.nnz, graph=g,
    )


def vcover_lp(g: Graph) -> Problem:
    """min <1,x> : M^T x >= 1 (eq. 10). LP duality: LP(vcover) = LP(match),
    so greedy matching g_m gives bounds [g_m, 2 g_m]."""
    C = Transposed(Incidence(u=jnp.asarray(g.u), v=jnp.asarray(g.v), n_vertices=g.n))
    gm = max(baselines.greedy_maximal_matching(g), 1)
    lo, hi = max(float(gm) * 0.5, 0.5), float(2.0 * gm)
    return Problem(
        name="vcover", kind="covering", sense="min", bound_mode="objective_packing",
        C=C, c=jnp.ones((g.n,)), lo=lo, hi=hi, n_vars=g.n, nnz=C.nnz, graph=g,
    )


def domset_lp(g: Graph) -> Problem:
    """min <1,x> : (I+A) x >= 1 (eq. 8). Greedy set-cover bound:
    greedy g_d <= (ln(Delta+1)+1) LP  =>  LP in [g_d / (ln(D+1)+1), g_d]."""
    C = AdjacencyPlusId(u=jnp.asarray(g.u), v=jnp.asarray(g.v), n_vertices=g.n)
    gd = max(baselines.greedy_dominating_set(g), 1)
    dmax = int(g.degrees().max(initial=1))
    lo = max(float(gd) / (np.log(dmax + 1.0) + 1.0) * 0.5, 0.25)
    hi = float(gd) + 1.0
    return Problem(
        name="dom-set", kind="covering", sense="min", bound_mode="objective_packing",
        C=C, c=jnp.ones((g.n,)), lo=lo, hi=hi, n_vars=g.n, nnz=C.nnz, graph=g,
    )


def densest_subgraph_lp(g: Graph) -> Problem:
    """min D : Wz >= 1, Oz <= D (eq. 15). Charikar peel rho_g: rho* in
    [rho_g, 2 rho_g]; D feasible iff D >= rho*.

    Declarative form of the old ``make_PC`` closure: the density bound D
    scales the packing rows (``bound_mode="scale_packing"``), so bounds
    enter through an array leaf and the search can be vmap-batched.
    """
    u, v = jnp.asarray(g.u), jnp.asarray(g.v)
    W = InterweavedId(n_edges=g.m)
    O = VertexEdgePair(u=u, v=v, n_vertices=g.n)
    rho_g, _ = baselines.charikar_peel(g)
    rho_g = max(rho_g, 0.5)
    lo, hi = rho_g * 0.999, 2.0 * rho_g + 1.0
    return Problem(
        name="dense-sub", kind="densest", sense="min", bound_mode="scale_packing",
        P=O, C=W, lo=lo, hi=hi, n_vars=2 * g.m, nnz=W.nnz + O.nnz, graph=g,
    )


def generalized_matching_lp(g: Graph, lb: np.ndarray, ub: np.ndarray):
    """Feasibility: lb <= M x <= ub, x in [0,1]^m (Appendix A.1).

    Returns (P, C, c_mask) ready for core.solve: rows are normalized to
    1-RHS (P = diag(1/ub) M ; C = diag(1/lb) M with lb==0 rows masked).
    The x <= 1 box is appended as packing rows via an identity operator
    encoded as a Coo.
    """
    u, v = jnp.asarray(g.u), jnp.asarray(g.v)
    M = Incidence(u=u, v=v, n_vertices=g.n)
    ub = np.maximum(np.asarray(ub, np.float64), 1e-12)
    lb = np.asarray(lb, np.float64)
    degree_rows = ScaledRows(scale=jnp.asarray(1.0 / ub), inner=M)
    eye = jnp.arange(g.m, dtype=jnp.int32)
    box_rows = Coo(rows=eye, cols=eye, vals=jnp.ones((g.m,)), _shape=(g.m, g.m))
    P = VStack(ops=(degree_rows, box_rows))
    lb_safe = np.where(lb > 0, lb, 1.0)
    C = ScaledRows(scale=jnp.asarray(1.0 / lb_safe), inner=M)
    c_mask = jnp.asarray(lb > 0)
    return P, C, c_mask


def generalized_matching_problem(g: Graph, lb: np.ndarray, ub: np.ndarray) -> Problem:
    """Declarative :class:`Problem` form of :func:`generalized_matching_lp`."""
    P, C, c_mask = generalized_matching_lp(g, lb, ub)
    return Problem(
        name="gen-match", kind="mixed", sense="feasibility", bound_mode="none",
        P=P, C=C, c_mask=c_mask, n_vars=g.m, nnz=P.nnz + C.nnz, graph=g,
    )


PROBLEMS = {
    "match": matching_lp,
    "bmatch": bmatching_lp,
    "vcover": vcover_lp,
    "dom-set": domset_lp,
    "dense-sub": densest_subgraph_lp,
}


def build(problem: str, g: Graph) -> Problem:
    return PROBLEMS[problem](g)
