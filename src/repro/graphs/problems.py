"""Graph problems as positive LPs (paper §3).

Each builder returns a :class:`ProblemLP` bundling the implicit operators,
the objective, binary-search bounds derived from combinatorial heuristics
(graphs/baselines.py), and a solve() entry point dispatching to the right
feasibility driver.

| problem    | LP                                   | type          |
|------------|--------------------------------------|---------------|
| match      | max 1.x : M x <= 1                   | pure packing  |
| bmatch     | same, bipartite input                | pure packing  |
| vcover     | min 1.x : M^T x >= 1                 | pure covering |
| dom-set    | min 1.x : (I+A) x >= 1               | pure covering |
| dense-sub  | min D : W z >= 1, O z <= D 1         | mixed, D-search |
| gen-match  | exists x: M x <= ub, M x >= lb       | mixed feasibility |
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax.numpy as jnp
import numpy as np

from ..core import (
    AdjacencyPlusId,
    Incidence,
    InterweavedId,
    MWUOptions,
    ScaledRows,
    Transposed,
    VertexEdgePair,
    densest_subgraph_search,
    maximize_packing,
    minimize_covering,
    solve,
)
from . import baselines
from .graph import Graph

__all__ = ["ProblemLP", "matching_lp", "bmatching_lp", "vcover_lp", "domset_lp",
           "densest_subgraph_lp", "generalized_matching_lp", "build", "PROBLEMS"]


@dataclass
class ProblemLP:
    name: str
    kind: str  # "packing" | "covering" | "densest" | "mixed"
    graph: Graph
    n_vars: int
    solve_fn: Callable  # (MWUOptions) -> BinarySearchResult-like
    lo: float
    hi: float
    sense: str  # "max" | "min" | "feasibility"
    # diagnostics for benchmarks
    nnz: int = 0

    def solve(self, opts: MWUOptions = MWUOptions()):
        return self.solve_fn(opts)


def matching_lp(g: Graph, name="match") -> ProblemLP:
    """max <1,x> : Mx <= 1 (eq. 6). Bounds via greedy maximal matching:
    greedy g_m has nu_int <= 2 g_m, and LP <= 3/2 nu_int <= 3 g_m."""
    P = Incidence(u=jnp.asarray(g.u), v=jnp.asarray(g.v), n_vertices=g.n)
    gm = max(baselines.greedy_maximal_matching(g), 1)
    lo, hi = float(gm), float(min(3.0 * gm, g.n / 2.0) + 1.0)
    c = jnp.ones((g.m,))

    def run(opts):
        return maximize_packing(P, c, lo, hi, opts)

    return ProblemLP(name, "packing", g, g.m, run, lo, hi, "max", nnz=P.nnz)


def bmatching_lp(g: Graph) -> ProblemLP:
    """Bipartite matching: LP is integral (no gap); bounds [g_m, 2 g_m]."""
    assert g.bipartite_split is not None, "bmatch requires a bipartite graph"
    P = Incidence(u=jnp.asarray(g.u), v=jnp.asarray(g.v), n_vertices=g.n)
    gm = max(baselines.greedy_maximal_matching(g), 1)
    lo, hi = float(gm), float(2.0 * gm + 1.0)
    c = jnp.ones((g.m,))

    def run(opts):
        return maximize_packing(P, c, lo, hi, opts)

    return ProblemLP("bmatch", "packing", g, g.m, run, lo, hi, "max", nnz=P.nnz)


def vcover_lp(g: Graph) -> ProblemLP:
    """min <1,x> : M^T x >= 1 (eq. 10). LP duality: LP(vcover) = LP(match),
    so greedy matching g_m gives bounds [g_m, 2 g_m]."""
    C = Transposed(Incidence(u=jnp.asarray(g.u), v=jnp.asarray(g.v), n_vertices=g.n))
    gm = max(baselines.greedy_maximal_matching(g), 1)
    lo, hi = max(float(gm) * 0.5, 0.5), float(2.0 * gm)
    c = jnp.ones((g.n,))

    def run(opts):
        return minimize_covering(C, c, lo, hi, opts)

    return ProblemLP("vcover", "covering", g, g.n, run, lo, hi, "min", nnz=C.nnz)


def domset_lp(g: Graph) -> ProblemLP:
    """min <1,x> : (I+A) x >= 1 (eq. 8). Greedy set-cover bound:
    greedy g_d <= (ln(Delta+1)+1) LP  =>  LP in [g_d / (ln(D+1)+1), g_d]."""
    C = AdjacencyPlusId(u=jnp.asarray(g.u), v=jnp.asarray(g.v), n_vertices=g.n)
    gd = max(baselines.greedy_dominating_set(g), 1)
    dmax = int(g.degrees().max(initial=1))
    lo = max(float(gd) / (np.log(dmax + 1.0) + 1.0) * 0.5, 0.25)
    hi = float(gd) + 1.0
    c = jnp.ones((g.n,))

    def run(opts):
        return minimize_covering(C, c, lo, hi, opts)

    return ProblemLP("dom-set", "covering", g, g.n, run, lo, hi, "min", nnz=C.nnz)


def densest_subgraph_lp(g: Graph) -> ProblemLP:
    """min D : Wz >= 1, Oz <= D (eq. 15). Charikar peel rho_g: rho* in
    [rho_g, 2 rho_g]; D feasible iff D >= rho*."""
    u, v = jnp.asarray(g.u), jnp.asarray(g.v)
    W = InterweavedId(n_edges=g.m)
    O = VertexEdgePair(u=u, v=v, n_vertices=g.n)
    rho_g, _ = baselines.charikar_peel(g)
    rho_g = max(rho_g, 0.5)
    lo, hi = rho_g * 0.999, 2.0 * rho_g + 1.0

    def make_PC(D):
        P = ScaledRows(scale=jnp.full((g.n,), 1.0 / D), inner=O)
        return P, W

    def run(opts):
        return densest_subgraph_search(make_PC, lo, hi, opts)

    return ProblemLP("dense-sub", "densest", g, 2 * g.m, run, lo, hi, "min",
                     nnz=W.nnz + O.nnz)


def generalized_matching_lp(g: Graph, lb: np.ndarray, ub: np.ndarray):
    """Feasibility: lb <= M x <= ub, x in [0,1]^m (Appendix A.1).

    Returns (P, C, c_mask) ready for core.solve: rows are normalized to
    1-RHS (P = diag(1/ub) M ; C = diag(1/lb) M with lb==0 rows masked).
    The x <= 1 box is appended as packing rows via an identity operator
    encoded as a Coo.
    """
    import jax

    u, v = jnp.asarray(g.u), jnp.asarray(g.v)
    M = Incidence(u=u, v=v, n_vertices=g.n)
    ub = np.maximum(np.asarray(ub, np.float64), 1e-12)
    lb = np.asarray(lb, np.float64)
    P = ScaledRows(scale=jnp.asarray(1.0 / ub), inner=M)
    lb_safe = np.where(lb > 0, lb, 1.0)
    C = ScaledRows(scale=jnp.asarray(1.0 / lb_safe), inner=M)
    c_mask = jnp.asarray(lb > 0)
    return P, C, c_mask


PROBLEMS = {
    "match": matching_lp,
    "bmatch": bmatching_lp,
    "vcover": vcover_lp,
    "dom-set": domset_lp,
    "dense-sub": densest_subgraph_lp,
}


def build(problem: str, g: Graph) -> ProblemLP:
    return PROBLEMS[problem](g)
