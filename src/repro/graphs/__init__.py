"""Graph problems as positive LPs (paper §3) + generators + baselines."""
from .graph import Graph
from .generators import bipartite_ratings, erdos, grid2d, kron, rgg
from .problems import (
    PROBLEMS,
    ProblemLP,
    bmatching_lp,
    build,
    densest_subgraph_lp,
    domset_lp,
    generalized_matching_lp,
    matching_lp,
    vcover_lp,
)

__all__ = [
    "Graph",
    "rgg",
    "kron",
    "erdos",
    "grid2d",
    "bipartite_ratings",
    "PROBLEMS",
    "ProblemLP",
    "build",
    "matching_lp",
    "bmatching_lp",
    "vcover_lp",
    "domset_lp",
    "densest_subgraph_lp",
    "generalized_matching_lp",
]
