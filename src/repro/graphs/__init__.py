"""Graph problems as positive LPs (paper §3) + generators + baselines.

The builders here return declarative :class:`repro.api.Problem` specs;
solve them with :class:`repro.api.Solver` (the canonical entry point)
or via the ``Problem.solve`` convenience. ``ProblemLP`` is a deprecated
alias of ``Problem``.
"""
from .graph import Graph
from .generators import bipartite_ratings, erdos, grid2d, kron, rgg
from .problems import (
    PROBLEMS,
    bmatching_lp,
    build,
    densest_subgraph_lp,
    domset_lp,
    generalized_matching_lp,
    generalized_matching_problem,
    matching_lp,
    vcover_lp,
)


def __getattr__(name):
    # deprecated re-exports resolve lazily so importing repro.graphs
    # stays warning-free; the warning fires on first actual use.
    if name == "ProblemLP":
        from . import problems

        return problems.ProblemLP
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "Graph",
    "rgg",
    "kron",
    "erdos",
    "grid2d",
    "bipartite_ratings",
    "PROBLEMS",
    "ProblemLP",
    "build",
    "matching_lp",
    "bmatching_lp",
    "vcover_lp",
    "domset_lp",
    "densest_subgraph_lp",
    "generalized_matching_lp",
    "generalized_matching_problem",
]
