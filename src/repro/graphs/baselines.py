"""Combinatorial + exact baselines (paper §6.2 comparison targets).

Stand-ins for the paper's external baselines, all runnable offline:

* ``scipy.optimize.linprog`` (HiGHS) — plays CPLEX/Gurobi: exact
  fractional LP solutions.
* ``scipy.sparse.csgraph.maximum_bipartite_matching`` (Hopcroft–Karp in
  C) — plays *ms-bfs-graft* for bmatch.
* ``charikar_peel`` — Charikar's greedy 2-approximation for densest
  subgraph — plays *GBBS*.
* greedy maximal matching / greedy dominating set / matching-based
  2-approx vertex cover — classic heuristics used both as comparison
  points and as binary-search bound providers for the MWU drivers.
"""
from __future__ import annotations

import heapq
import time

import numpy as np
import scipy.sparse as sp

from .graph import Graph

__all__ = [
    "greedy_maximal_matching",
    "hopcroft_karp_bmatch",
    "greedy_dominating_set",
    "matching_vertex_cover",
    "charikar_peel",
    "exact_lp",
]


def greedy_maximal_matching(g: Graph) -> int:
    """Size of a greedy maximal matching (>= 1/2 of maximum)."""
    used = np.zeros(g.n, bool)
    cnt = 0
    for a, b in zip(g.u, g.v):
        if not used[a] and not used[b]:
            used[a] = used[b] = True
            cnt += 1
    return cnt


def hopcroft_karp_bmatch(g: Graph) -> int:
    """Exact maximum bipartite matching via scipy (C Hopcroft–Karp)."""
    assert g.bipartite_split is not None
    s = g.bipartite_split
    rows = g.u
    cols = g.v - s
    biadj = sp.csr_matrix(
        (np.ones(g.m, np.int8), (rows, cols)), shape=(s, g.n - s)
    )
    match = sp.csgraph.maximum_bipartite_matching(biadj, perm_type="column")
    return int((match >= 0).sum())


def greedy_dominating_set(g: Graph) -> int:
    """Greedy set cover specialization: lazy-heap max-coverage."""
    ptr, adj, _ = g.adjacency_lists()
    covered = np.zeros(g.n, bool)
    # gain(v) = |{v} ∪ N(v) uncovered|
    gain = (ptr[1:] - ptr[:-1]) + 1
    heap = [(-int(gain[i]), i) for i in range(g.n)]
    heapq.heapify(heap)
    n_cov = 0
    size = 0
    while n_cov < g.n:
        negg, v = heapq.heappop(heap)
        # lazy re-evaluation
        nbrs = adj[ptr[v] : ptr[v + 1]]
        cur = int(~covered[v]) + int((~covered[nbrs]).sum())
        if cur == 0:
            continue
        if -negg != cur:
            heapq.heappush(heap, (-cur, v))
            continue
        size += 1
        if not covered[v]:
            covered[v] = True
            n_cov += 1
        newly = nbrs[~covered[nbrs]]
        covered[newly] = True
        n_cov += len(newly)
    return size


def matching_vertex_cover(g: Graph) -> int:
    """2-approx vertex cover: both endpoints of a maximal matching."""
    return 2 * greedy_maximal_matching(g)


def charikar_peel(g: Graph):
    """Charikar's greedy peel: exact on the peel sequence, 2-approx of rho*.

    Returns (best_density, best_size).
    """
    ptr, adj, _ = g.adjacency_lists()
    deg = (ptr[1:] - ptr[:-1]).astype(np.int64)
    alive = np.ones(g.n, bool)
    m_alive = g.m
    n_alive = g.n
    heap = [(int(deg[i]), i) for i in range(g.n)]
    heapq.heapify(heap)
    best = (m_alive / max(n_alive, 1), n_alive)
    while n_alive > 1:
        d, v = heapq.heappop(heap)
        if not alive[v] or d != deg[v]:
            continue
        alive[v] = False
        m_alive -= deg[v]
        n_alive -= 1
        for w in adj[ptr[v] : ptr[v + 1]]:
            if alive[w]:
                deg[w] -= 1
                heapq.heappush(heap, (int(deg[w]), int(w)))
        dens = m_alive / max(n_alive, 1)
        if dens > best[0]:
            best = (dens, n_alive)
    return best


# ----------------------------------------------------------------------
# Exact LP baselines via scipy/HiGHS (the CPLEX/Gurobi role)
# ----------------------------------------------------------------------

def _incidence_sparse(g: Graph) -> sp.csr_matrix:
    rows = np.concatenate([g.u, g.v])
    cols = np.tile(np.arange(g.m), 2)
    return sp.csr_matrix((np.ones(2 * g.m), (rows, cols)), shape=(g.n, g.m))


def exact_lp(problem: str, g: Graph):
    """Solve the exact LP relaxation with HiGHS; returns (value, seconds).

    Problems: match/bmatch (max 1.x : Mx<=1), vcover (min 1.x : M^T x>=1),
    dom-set (min 1.x : (I+A)x>=1), dense-sub (min D : Wz>=1, Oz<=D).
    """
    from scipy.optimize import linprog

    t0 = time.perf_counter()
    if problem in ("match", "bmatch"):
        M = _incidence_sparse(g)
        res = linprog(
            c=-np.ones(g.m), A_ub=M, b_ub=np.ones(g.n), bounds=(0, None), method="highs"
        )
        val = -res.fun
    elif problem == "vcover":
        M = _incidence_sparse(g)
        res = linprog(
            c=np.ones(g.n), A_ub=-M.T.tocsr(), b_ub=-np.ones(g.m), bounds=(0, None), method="highs"
        )
        val = res.fun
    elif problem == "dom-set":
        rows = np.concatenate([g.u, g.v, np.arange(g.n)])
        cols = np.concatenate([g.v, g.u, np.arange(g.n)])
        IA = sp.csr_matrix((np.ones(len(rows)), (rows, cols)), shape=(g.n, g.n))
        res = linprog(
            c=np.ones(g.n), A_ub=-IA, b_ub=-np.ones(g.n), bounds=(0, None), method="highs"
        )
        val = res.fun
    elif problem == "dense-sub":
        # vars = (z in R^{2m}, D); min D ; -Wz <= -1 ; Oz - D 1 <= 0
        m, n = g.m, g.n
        W = sp.csr_matrix(
            (np.ones(2 * m), (np.repeat(np.arange(m), 2), np.arange(2 * m))),
            shape=(m, 2 * m),
        )
        O = sp.csr_matrix(
            (
                np.ones(2 * m),
                (
                    np.stack([g.u, g.v], axis=1).ravel(),
                    np.arange(2 * m),
                ),
            ),
            shape=(n, 2 * m),
        )
        A1 = sp.hstack([-W, sp.csr_matrix((m, 1))])
        A2 = sp.hstack([O, sp.csr_matrix(-np.ones((n, 1)))])
        A = sp.vstack([A1, A2]).tocsr()
        b = np.concatenate([-np.ones(m), np.zeros(n)])
        c = np.zeros(2 * m + 1)
        c[-1] = 1.0
        res = linprog(c=c, A_ub=A, b_ub=b, bounds=(0, None), method="highs")
        val = res.fun
    else:
        raise ValueError(problem)
    if not res.success:
        raise RuntimeError(f"HiGHS failed on {problem}: {res.message}")
    return float(val), time.perf_counter() - t0
