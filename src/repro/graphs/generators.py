"""Synthetic graph generators mirroring the paper's input suite (Table 1).

* ``rgg(k)``   — random geometric graphs rgg-k (2^k vertices, planar-like),
                 matching SuiteSparse's rgg_n_2_k family: radius chosen so
                 expected degree ~ 15 (paper lists |E| ~ 15 |V|).
* ``kron(k)``  — Graph500-style stochastic Kronecker graphs kron-k
                 (2^k vertices, |E| ~ 80 |V|... here edgefactor is an
                 argument, default 16 to keep CPU benchmarks tractable),
                 strong community structure / power-law degrees.
* ``erdos``    — Erdős–Rényi G(n, m) control.
* ``bipartite_ratings`` — Netflix/KDD-like user-item bipartite graphs for
                 the generalized-matching study (Appendix A.1/A.2).

All generators are deterministic in ``seed`` (numpy Generator).
"""
from __future__ import annotations

import numpy as np

from .graph import Graph

__all__ = ["rgg", "kron", "erdos", "bipartite_ratings", "grid2d"]


def rgg(scale: int, seed: int = 0, target_degree: float = 15.0) -> Graph:
    """Random geometric graph with 2^scale vertices on the unit square.

    Connects points within radius r where pi r^2 n = target_degree.
    Uses a cell grid for O(n) expected neighbor search.
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    pts = rng.random((n, 2))
    r = float(np.sqrt(target_degree / (np.pi * n)))
    cells = max(1, int(1.0 / r))
    cx = np.minimum((pts[:, 0] * cells).astype(np.int64), cells - 1)
    cy = np.minimum((pts[:, 1] * cells).astype(np.int64), cells - 1)
    cell_id = cx * cells + cy
    order = np.argsort(cell_id, kind="stable")
    sorted_cell = cell_id[order]
    # cell -> slice of `order`
    starts = np.searchsorted(sorted_cell, np.arange(cells * cells))
    ends = np.searchsorted(sorted_cell, np.arange(cells * cells), side="right")

    edges = []
    r2 = r * r
    for dxy in ((0, 0), (1, 0), (0, 1), (1, 1), (1, -1)):
        dx, dy = dxy
        # pair points in cell (i,j) with cell (i+dx, j+dy)
        src_cells = np.arange(cells * cells)
        sx, sy = src_cells // cells, src_cells % cells
        tx, ty = sx + dx, sy + dy
        ok = (tx >= 0) & (tx < cells) & (ty >= 0) & (ty < cells)
        for c_src, c_tgt in zip(src_cells[ok], (tx * cells + ty)[ok]):
            a = order[starts[c_src] : ends[c_src]]
            b = order[starts[c_tgt] : ends[c_tgt]]
            if len(a) == 0 or len(b) == 0:
                continue
            d = pts[a][:, None, :] - pts[b][None, :, :]
            close = (d * d).sum(-1) <= r2
            ia, ib = np.nonzero(close)
            if dx == 0 and dy == 0:
                keep = a[ia] < b[ib]
                ia, ib = ia[keep], ib[keep]
            if len(ia):
                edges.append(np.stack([a[ia], b[ib]], axis=1))
    e = np.concatenate(edges) if edges else np.zeros((0, 2), np.int64)
    return Graph.from_edges(n, e, name=f"rgg-{scale}")


def kron(scale: int, seed: int = 0, edgefactor: int = 16) -> Graph:
    """Graph500 stochastic Kronecker generator (A=.57,B=.19,C=.19,D=.05)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edgefactor * n
    A, B, C = 0.57, 0.19, 0.19
    ij = np.zeros((2, m), np.int64)
    ab = A + B
    c_norm = C / (1 - ab)
    a_norm = A / ab
    for ib in range(scale):
        ii_bit = rng.random(m) > ab
        jj_bit = rng.random(m) > np.where(ii_bit, c_norm, a_norm)
        ij[0] += (1 << ib) * ii_bit
        ij[1] += (1 << ib) * jj_bit
    perm = rng.permutation(n)  # relabel to hide locality (Graph500 step)
    ij = perm[ij]
    return Graph.from_edges(n, ij.T, name=f"kron-{scale}")


def erdos(n: int, m: int, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(int(m * 1.3) + 8, 2))
    g = Graph.from_edges(n, e, name=f"er-{n}")
    if g.m > m:
        keep = rng.choice(g.m, size=m, replace=False)
        g = Graph(n=n, u=g.u[keep], v=g.v[keep], name=g.name)
        order = np.argsort(g.u * n + g.v)
        g = Graph(n=n, u=g.u[order], v=g.v[order], name=g.name)
    return g


def grid2d(side: int) -> Graph:
    """side x side grid graph — known matching/cover numbers for tests."""
    idx = np.arange(side * side).reshape(side, side)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1)
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1)
    return Graph.from_edges(side * side, np.concatenate([right, down]), name=f"grid-{side}")


def bipartite_ratings(
    n_users: int,
    n_items: int,
    avg_ratings: float = 20.0,
    seed: int = 0,
    zipf_a: float = 1.5,
) -> Graph:
    """User-item bipartite graph with power-law item popularity.

    Mirrors the Netflix/KDD structure of Appendix A.2: users on the left
    [0, n_users), items on the right [n_users, n_users + n_items); edges =
    ratings. Item popularity ~ Zipf, user activity ~ Poisson(avg_ratings),
    min 10 ratings per user (the paper excludes <10-rating users).
    """
    rng = np.random.default_rng(seed)
    n_ratings = np.maximum(rng.poisson(avg_ratings, size=n_users), 10)
    total = int(n_ratings.sum())
    users = np.repeat(np.arange(n_users), n_ratings)
    # zipf-ish item choice via inverse-CDF on a truncated power law
    ranks = (rng.pareto(zipf_a - 1.0, size=total) + 1.0)
    items = (n_items / ranks).astype(np.int64) % n_items
    items = n_users + items
    e = np.stack([users, items], axis=1)
    g = Graph.from_edges(n_users + n_items, e, name=f"ratings-{n_users}x{n_items}",
                         bipartite_split=n_users)
    return g
