"""Graph container used across the framework.

Edges are stored once (u < v canonical order for undirected graphs),
deduplicated, self-loop free — matching the paper's assumptions (§3).
Host-side state is numpy; ``device()`` returns jnp copies.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["Graph"]


@dataclass
class Graph:
    n: int  # |V|
    u: np.ndarray  # (m,) int32 endpoint 0
    v: np.ndarray  # (m,) int32 endpoint 1
    name: str = "graph"
    bipartite_split: int | None = None  # first right-vertex id for bipartite graphs

    def __post_init__(self):
        self.u = np.asarray(self.u, np.int32)
        self.v = np.asarray(self.v, np.int32)
        assert self.u.shape == self.v.shape

    @property
    def m(self) -> int:
        return int(self.u.shape[0])

    @staticmethod
    def from_edges(n: int, edges: np.ndarray, name: str = "graph", bipartite_split=None) -> "Graph":
        """Canonicalize: drop self loops, sort endpoints, dedupe."""
        e = np.asarray(edges, np.int64).reshape(-1, 2)
        e = e[e[:, 0] != e[:, 1]]
        lo = np.minimum(e[:, 0], e[:, 1])
        hi = np.maximum(e[:, 0], e[:, 1])
        key = lo * n + hi
        _, idx = np.unique(key, return_index=True)
        return Graph(n=n, u=lo[idx].astype(np.int32), v=hi[idx].astype(np.int32),
                     name=name, bipartite_split=bipartite_split)

    def degrees(self) -> np.ndarray:
        d = np.zeros(self.n, np.int64)
        np.add.at(d, self.u, 1)
        np.add.at(d, self.v, 1)
        return d

    def adjacency_lists(self):
        """CSR-style neighbor lists (host side, for combinatorial baselines)."""
        src = np.concatenate([self.u, self.v])
        dst = np.concatenate([self.v, self.u])
        eid = np.tile(np.arange(self.m, dtype=np.int32), 2)
        order = np.argsort(src, kind="stable")
        deg = np.bincount(src, minlength=self.n)
        ptr = np.zeros(self.n + 1, np.int64)
        np.cumsum(deg, out=ptr[1:])
        return ptr, dst[order].astype(np.int32), eid[order]

    def validate(self):
        assert self.u.min(initial=0) >= 0 and self.v.max(initial=-1) < self.n
        assert np.all(self.u < self.v), "edges must be canonical (u < v)"
        if self.bipartite_split is not None:
            s = self.bipartite_split
            assert np.all(self.u < s) and np.all(self.v >= s), "not bipartite"
        return True
