"""Trip-count-aware HLO-text analyzer for the roofline terms.

Why this exists: ``compiled.cost_analysis()`` visits each ``while`` body
exactly once, so a model scanned over L layers under-reports FLOPs,
bytes and (entirely absent) collective traffic by ~L x. The dry-run's
roofline (EXPERIMENTS.md §Roofline) therefore derives its three terms
from the *scheduled HLO text* of the compiled executable:

  * FLOPs       — 2 * numel(out) * K for every dot (batch/contracting
                  dims decoded from the dot attributes), plus a
                  1-flop/element estimate for fusion outputs;
  * HBM bytes   — per top-level op: operand + output sizes, where
                  operands of slice-like access patterns (dynamic-slice
                  / dynamic-update-slice / gather, including when fused)
                  are charged at their slice size — this is post-fusion
                  HBM traffic, not intra-fusion register traffic;
  * collective wire bytes per device — ring formulas per op kind:
        all-reduce         2 (g-1)/g * size
        all-gather           (g-1)/g * size          (size = output)
        reduce-scatter       (g-1)   * size          (size = output)
        all-to-all           (g-1)/g * size
        collective-permute             size

  with every ``while(cond, body)`` contribution multiplied by the trip
  count recovered from the loop-bound constant in the condition
  computation (max s32/s64 literal — exact for lax.scan/fori loops).

Validated against closed-form expectations in tests/test_hlo_analyzer.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "HloReport"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]+?\)?)\s+([a-z][a-z0-9\-]*)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_SKIP_BYTES = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast", "iota",
    "after-all", "broadcast", "reshape", "while", "conditional", "call",
    "custom-call", "partition-id", "replica-id", "domain", "opt-barrier",
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class _Op:
    name: str
    type_str: str
    kind: str
    rest: str  # operands + attrs (raw tail of the line)

    @property
    def operands(self):
        # operand names appear before the closing paren of the call
        depth = 0
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    head = self.rest[:i]
                    break
                depth -= 1
        else:
            head = self.rest
        return re.findall(r"%([\w.\-]+)", head)

    @property
    def attrs(self):
        return self.rest


@dataclass
class _Comp:
    name: str
    ops: list = field(default_factory=list)
    by_name: dict = field(default_factory=dict)


@dataclass
class HloReport:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_breakdown: dict = field(default_factory=dict)  # kind -> bytes
    dot_flops: float = 0.0
    fusion_flops: float = 0.0
    n_collectives: int = 0
    while_trips: dict = field(default_factory=dict)
    # (kind, output type, group size, trip-multiplied wire bytes) top items
    top_collectives: list = field(default_factory=list)

    def as_dict(self):
        return {
            "flops": self.flops,
            "dot_flops": self.dot_flops,
            "fusion_flops": self.fusion_flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_wire_bytes": self.collective_wire_bytes,
            "collective_breakdown": dict(self.collective_breakdown),
            "n_collectives": self.n_collectives,
            "while_trips": dict(self.while_trips),
            "top_collectives": list(self.top_collectives),
        }


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _parse(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for line in text.splitlines():
        if "/*" in line:  # strip /*index=N*/ tuple comments ('=' breaks _OP_RE)
            line = _COMMENT_RE.sub("", line)
        if cur is None:
            m = _COMP_RE.match(line)
            if m and ("->" in line):
                cur = _Comp(name=m.group(1))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = _Op(name=m.group(1), type_str=m.group(2), kind=m.group(3), rest=m.group(4))
            cur.ops.append(op)
            cur.by_name[op.name] = op
    return comps


def _group_size(attrs: str, num_partitions: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    return max(num_partitions, 1)


def _dot_flops(op: _Op, comp: _Comp) -> float:
    out_numel = 1
    for d in _shape_dims(op.type_str):
        out_numel *= d
    # contraction size from lhs operand shape
    lhs_name = op.operands[0] if op.operands else None
    lhs = comp.by_name.get(lhs_name)
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    if lhs is not None and m and m.group(1):
        dims = _shape_dims(lhs.type_str)
        for ci in m.group(1).split(","):
            ci = int(ci)
            if ci < len(dims):
                k *= dims[ci]
    return 2.0 * out_numel * k


def _trip_count(comps, cond_name: str) -> int:
    """Max integer literal in the condition computation (lax loop bound)."""
    best = 1
    seen = set()
    stack = [cond_name]
    while stack:
        cn = stack.pop()
        if cn in seen or cn not in comps:
            continue
        seen.add(cn)
        for op in comps[cn].ops:
            if op.kind == "constant":
                m = re.match(r"\s*(\d+)\)", op.rest)
                if m:
                    best = max(best, int(m.group(1)))
            for c in _CONST_RE.findall(op.rest):
                best = max(best, int(c))
            m = re.search(r"calls=%([\w.\-]+)", op.rest)
            if m:
                stack.append(m.group(1))
    return best


_PASS_THROUGH = {"bitcast", "reshape", "copy", "transpose", "convert", "bitcast-convert"}


def _fusion_param_charges(comps, fusion_comp: str) -> dict[int, float]:
    """Byte charge per fusion-parameter position for slice-accessed params.

    A parameter whose every use-path flows only through pass-through ops
    (bitcast/reshape/copy/transpose/convert) into the *sliced operand* of
    a dynamic-slice / gather / dynamic-update-slice is charged at the sum
    of the slice sizes (actual HBM traffic), not the full buffer — this
    is how scanned layer stacks read their per-iteration slice.
    Positions absent from the result are charged at full size.
    """
    comp = comps.get(fusion_comp)
    if comp is None:
        return {}
    param_pos: dict[str, int] = {}
    for op in comp.ops:
        if op.kind == "parameter":
            m = re.match(r"\s*(\d+)", op.rest)
            if m:
                param_pos[op.name] = int(m.group(1))
    # users map: name -> list[(op, operand_index)]
    users: dict[str, list] = {}
    for op in comp.ops:
        for i, o in enumerate(op.operands):
            users.setdefault(o, []).append((op, i))

    charges: dict[int, float] = {}
    for pname, pos in param_pos.items():
        ok = True
        slice_bytes = 0.0
        stack = [pname]
        seen = set()
        while stack and ok:
            cur = stack.pop()
            if cur in seen:
                continue
            seen.add(cur)
            for op, i in users.get(cur, []):
                if op.kind in _PASS_THROUGH:
                    stack.append(op.name)
                elif op.kind in ("dynamic-slice", "gather") and i == 0:
                    slice_bytes += _shape_bytes(op.type_str)
                elif op.kind == "dynamic-update-slice" and i == 0:
                    # in-place window update: charged via the update operand
                    upd = comp.by_name.get(op.operands[1])
                    slice_bytes += _shape_bytes(upd.type_str) if upd else _shape_bytes(op.type_str)
                else:
                    ok = False
                    break
        if ok and slice_bytes > 0:
            charges[pos] = slice_bytes
    return charges


def _op_bytes(op: _Op, comp: _Comp, comps) -> float:
    """Post-fusion HBM bytes for one top-level op."""
    out_b = _shape_bytes(op.type_str)
    if op.kind in ("dynamic-slice", "gather"):
        return 2.0 * out_b  # read slice + write output
    if op.kind == "dynamic-update-slice":
        upd = comp.by_name.get(op.operands[1]) if len(op.operands) > 1 else None
        ub = _shape_bytes(upd.type_str) if upd is not None else out_b
        return 2.0 * ub  # in-place: read+write the updated window
    total = float(out_b)
    charges: dict[int, float] = {}
    if op.kind == "fusion":
        m = re.search(r"calls=%([\w.\-]+)", op.rest)
        if m:
            charges = _fusion_param_charges(comps, m.group(1))
            inner = comps.get(m.group(1))
            if inner is not None:
                # fusion rooted in an in-place window update (e.g. the
                # remat stash write of a scanned layer stack): the write
                # traffic is the update slice, not the whole buffer.
                for iop in inner.ops:
                    if iop.kind == "dynamic-update-slice" and _shape_bytes(
                        iop.type_str
                    ) == out_b:
                        upd = inner.by_name.get(iop.operands[1]) if len(iop.operands) > 1 else None
                        if upd is not None:
                            total = float(_shape_bytes(upd.type_str))
                        break
    for i, name in enumerate(op.operands):
        src = comp.by_name.get(name)
        if src is None:
            continue
        if i in charges:
            total += min(charges[i], _shape_bytes(src.type_str))
            continue
        total += _shape_bytes(src.type_str)
    return total


def analyze_hlo(text: str, num_partitions: int = 1) -> HloReport:
    comps = _parse(text)
    rep = HloReport()
    memo: dict[str, tuple] = {}

    entry = None
    m = re.search(r"entry_computation_layout", text)
    # entry computation is the one marked ENTRY in the text
    em = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    if em:
        entry = em.group(1)
    if entry is None and comps:
        entry = list(comps)[-1]

    ZERO = (0.0, 0.0, 0.0, 0.0, {}, 0, [])

    def analyze_comp(name: str):
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None:
            return ZERO
        dflops = fflops = bytes_ = wire = 0.0
        coll: dict[str, float] = {}
        ncoll = 0
        items: list = []

        def absorb(res, mult=1):
            nonlocal dflops, fflops, bytes_, wire, ncoll
            df, ff, bb, bw, bc, bn, bi = res
            dflops += mult * df
            fflops += mult * ff
            bytes_ += mult * bb
            wire += mult * bw
            ncoll += mult * bn
            for k, v in bc.items():
                coll[k] = coll.get(k, 0.0) + mult * v
            for kind, ts, g, wb in bi:
                items.append((kind, ts, g, mult * wb))

        for op in comp.ops:
            kind = op.kind
            if kind == "while":
                cond = re.search(r"condition=%([\w.\-]+)", op.rest)
                body = re.search(r"body=%([\w.\-]+)", op.rest)
                trips = _trip_count(comps, cond.group(1)) if cond else 1
                rep.while_trips[op.name] = trips
                if body:
                    absorb(analyze_comp(body.group(1)), trips)
                continue
            if kind in ("call", "conditional", "async-start", "async-done"):
                for target in re.findall(r"(?:calls|to_apply|branch_computations)=\{?%?([\w.\-,% ]+)\}?", op.rest):
                    for t in re.findall(r"[\w.\-]+", target):
                        if t in comps:
                            absorb(analyze_comp(t))
                continue
            # collectives (match base kind; e.g. all-reduce-start)
            base = next((c for c in _COLLECTIVES if kind.startswith(c)), None)
            if base is not None:
                g = _group_size(op.rest, num_partitions)
                size = _shape_bytes(op.type_str)
                if base == "all-reduce":
                    w = 2.0 * (g - 1) / max(g, 1) * size
                elif base == "all-gather":
                    w = (g - 1) / max(g, 1) * size
                elif base == "reduce-scatter":
                    w = float(g - 1) * size
                elif base == "all-to-all":
                    w = (g - 1) / max(g, 1) * size
                else:
                    w = float(size)
                wire += w
                ncoll += 1
                coll[base] = coll.get(base, 0.0) + w
                items.append((base, op.type_str.strip(), g, w))
                bytes_ += _op_bytes(op, comp, comps)
                continue
            if kind == "dot":
                dflops += _dot_flops(op, comp)
                bytes_ += _op_bytes(op, comp, comps)
                continue
            if kind == "fusion":
                m2 = re.search(r"calls=%([\w.\-]+)", op.rest)
                if m2 and m2.group(1) in comps:
                    inner = comps[m2.group(1)]
                    for iop in inner.ops:
                        if iop.kind == "dot":
                            dflops += _dot_flops(iop, inner)
                        elif iop.kind not in _SKIP_BYTES:
                            n = 1
                            for d in _shape_dims(iop.type_str):
                                n *= d
                            fflops += n  # 1 flop/element estimate
                bytes_ += _op_bytes(op, comp, comps)
                continue
            if kind in _SKIP_BYTES:
                continue
            bytes_ += _op_bytes(op, comp, comps)
        memo[name] = (dflops, fflops, bytes_, wire, coll, ncoll, items)
        return memo[name]

    df, ff, b, w, c, n, items = analyze_comp(entry)
    rep.dot_flops = df
    rep.fusion_flops = ff
    rep.flops = df + ff
    rep.hbm_bytes = b
    rep.collective_wire_bytes = w
    rep.collective_breakdown = c
    rep.n_collectives = n
    # aggregate identical (kind, type, group) and keep the heaviest 12
    agg: dict = {}
    cnt: dict = {}
    for kind, ts, g, wb in items:
        key = (kind, ts, g)
        agg[key] = agg.get(key, 0.0) + wb
        cnt[key] = cnt.get(key, 0) + 1
    top = sorted(agg.items(), key=lambda kv: -kv[1])[:12]
    rep.top_collectives = [
        {"kind": k[0], "type": k[1][:60], "group": k[2], "wire_bytes": v,
         "count": cnt[k]}
        for k, v in top
    ]
    return rep
