"""Trip-count-aware HLO-text analyzer for the roofline terms.

Why this exists: ``compiled.cost_analysis()`` visits each ``while`` body
exactly once, so a model scanned over L layers under-reports FLOPs,
bytes and (entirely absent) collective traffic by ~L x. The dry-run's
roofline (EXPERIMENTS.md §Roofline) therefore derives its three terms
from the *scheduled HLO text* of the compiled executable:

  * FLOPs       — 2 * numel(out) * K for every dot (batch/contracting
                  dims decoded from the dot attributes), plus a
                  1-flop/element estimate for fusion outputs;
  * HBM bytes   — per top-level op: operand + output sizes, where
                  operands of slice-like access patterns (dynamic-slice
                  / dynamic-update-slice / gather, including when fused)
                  are charged at their slice size — this is post-fusion
                  HBM traffic, not intra-fusion register traffic;
  * collective wire bytes per device — ring formulas per op kind:
        all-reduce         2 (g-1)/g * size
        all-gather           (g-1)/g * size          (size = output)
        reduce-scatter       (g-1)   * size          (size = output)
        all-to-all           (g-1)/g * size
        collective-permute             size

  with every ``while(cond, body)`` contribution multiplied by the trip
  count recovered from the condition computation (the constants feeding
  its loop-bound compare — exact for lax.scan/fori loops).

The HLO text parser itself lives in :mod:`repro.tracecheck.hlo_ir`,
shared with the static-analysis gate so the roofline and the linter
read one IR. Validated against closed-form expectations in
tests/test_hlo_analyzer.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from ..tracecheck.hlo_ir import (
    Computation,
    Op,
    group_size,
    parse_hlo,
    shape_bytes,
    shape_dims,
    trip_count,
)

__all__ = ["analyze_hlo", "HloReport"]

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_SKIP_BYTES = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast", "iota",
    "after-all", "broadcast", "reshape", "while", "conditional", "call",
    "custom-call", "partition-id", "replica-id", "domain", "opt-barrier",
}


@dataclass
class HloReport:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_breakdown: dict = field(default_factory=dict)  # kind -> bytes
    dot_flops: float = 0.0
    fusion_flops: float = 0.0
    n_collectives: int = 0
    while_trips: dict = field(default_factory=dict)
    # (kind, output type, group size, trip-multiplied wire bytes) top items
    top_collectives: list = field(default_factory=list)

    def as_dict(self):
        return {
            "flops": self.flops,
            "dot_flops": self.dot_flops,
            "fusion_flops": self.fusion_flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_wire_bytes": self.collective_wire_bytes,
            "collective_breakdown": dict(self.collective_breakdown),
            "n_collectives": self.n_collectives,
            "while_trips": dict(self.while_trips),
            "top_collectives": list(self.top_collectives),
        }


def _dot_flops(op: Op, comp: Computation) -> float:
    out_numel = 1
    for d in shape_dims(op.type_str):
        out_numel *= d
    # contraction size from lhs operand shape
    lhs_name = op.operands[0] if op.operands else None
    lhs = comp.by_name.get(lhs_name)
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    if lhs is not None and m and m.group(1):
        dims = shape_dims(lhs.type_str)
        for ci in m.group(1).split(","):
            ci = int(ci)
            if ci < len(dims):
                k *= dims[ci]
    return 2.0 * out_numel * k


_PASS_THROUGH = {"bitcast", "reshape", "copy", "transpose", "convert", "bitcast-convert"}


def _fusion_param_charges(comps, fusion_comp: str) -> dict[int, float]:
    """Byte charge per fusion-parameter position for slice-accessed params.

    A parameter whose every use-path flows only through pass-through ops
    (bitcast/reshape/copy/transpose/convert) into the *sliced operand* of
    a dynamic-slice / gather / dynamic-update-slice is charged at the sum
    of the slice sizes (actual HBM traffic), not the full buffer — this
    is how scanned layer stacks read their per-iteration slice.
    Positions absent from the result are charged at full size.
    """
    comp = comps.get(fusion_comp)
    if comp is None:
        return {}
    param_pos: dict[str, int] = {}
    for op in comp.ops:
        if op.kind == "parameter":
            m = re.match(r"\s*(\d+)", op.rest)
            if m:
                param_pos[op.name] = int(m.group(1))
    # users map: name -> list[(op, operand_index)]
    users: dict[str, list] = {}
    for op in comp.ops:
        for i, o in enumerate(op.operands):
            users.setdefault(o, []).append((op, i))

    charges: dict[int, float] = {}
    for pname, pos in param_pos.items():
        ok = True
        slice_bytes = 0.0
        stack = [pname]
        seen = set()
        while stack and ok:
            cur = stack.pop()
            if cur in seen:
                continue
            seen.add(cur)
            for op, i in users.get(cur, []):
                if op.kind in _PASS_THROUGH:
                    stack.append(op.name)
                elif op.kind in ("dynamic-slice", "gather") and i == 0:
                    slice_bytes += shape_bytes(op.type_str)
                elif op.kind == "dynamic-update-slice" and i == 0:
                    # in-place window update: charged via the update operand
                    upd = comp.by_name.get(op.operands[1])
                    slice_bytes += shape_bytes(upd.type_str) if upd else shape_bytes(op.type_str)
                else:
                    ok = False
                    break
        if ok and slice_bytes > 0:
            charges[pos] = slice_bytes
    return charges


def _op_bytes(op: Op, comp: Computation, comps) -> float:
    """Post-fusion HBM bytes for one top-level op."""
    out_b = shape_bytes(op.type_str)
    if op.kind in ("dynamic-slice", "gather"):
        return 2.0 * out_b  # read slice + write output
    if op.kind == "dynamic-update-slice":
        upd = comp.by_name.get(op.operands[1]) if len(op.operands) > 1 else None
        ub = shape_bytes(upd.type_str) if upd is not None else out_b
        return 2.0 * ub  # in-place: read+write the updated window
    total = float(out_b)
    charges: dict[int, float] = {}
    if op.kind == "fusion":
        m = re.search(r"calls=%([\w.\-]+)", op.rest)
        if m:
            charges = _fusion_param_charges(comps, m.group(1))
            inner = comps.get(m.group(1))
            if inner is not None:
                # fusion rooted in an in-place window update (e.g. the
                # remat stash write of a scanned layer stack): the write
                # traffic is the update slice, not the whole buffer.
                for iop in inner.ops:
                    if iop.kind == "dynamic-update-slice" and shape_bytes(
                        iop.type_str
                    ) == out_b:
                        upd = inner.by_name.get(iop.operands[1]) if len(iop.operands) > 1 else None
                        if upd is not None:
                            total = float(shape_bytes(upd.type_str))
                        break
    for i, name in enumerate(op.operands):
        src = comp.by_name.get(name)
        if src is None:
            continue
        if i in charges:
            total += min(charges[i], shape_bytes(src.type_str))
            continue
        total += shape_bytes(src.type_str)
    return total


def analyze_hlo(text, num_partitions: int = 1, *, root: str | None = None) -> HloReport:
    """Cost accounting over ``text`` (HLO string or pre-parsed HloModule).

    ``root`` selects the computation to account from (default: ENTRY).
    The tracecheck cost model passes a ``while`` *body* computation here
    to get per-iteration cost — nested loops inside the body are still
    trip-multiplied, the selected loop itself is counted once.
    """
    mod = text if hasattr(text, "comps") else parse_hlo(text)
    comps = mod.comps
    rep = HloReport()
    memo: dict[str, tuple] = {}
    entry = root if root is not None else mod.entry

    ZERO = (0.0, 0.0, 0.0, 0.0, {}, 0, [])

    def analyze_comp(name: str):
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None:
            return ZERO
        dflops = fflops = bytes_ = wire = 0.0
        coll: dict[str, float] = {}
        ncoll = 0
        items: list = []

        def absorb(res, mult=1):
            nonlocal dflops, fflops, bytes_, wire, ncoll
            df, ff, bb, bw, bc, bn, bi = res
            dflops += mult * df
            fflops += mult * ff
            bytes_ += mult * bb
            wire += mult * bw
            ncoll += mult * bn
            for k, v in bc.items():
                coll[k] = coll.get(k, 0.0) + mult * v
            for kind, ts, g, wb in bi:
                items.append((kind, ts, g, mult * wb))

        for op in comp.ops:
            kind = op.kind
            if kind == "while":
                cond = re.search(r"condition=%([\w.\-]+)", op.rest)
                body = re.search(r"body=%([\w.\-]+)", op.rest)
                # trip_count returns None for data-dependent loops; the
                # roofline then counts the body once (a lower bound)
                trips = trip_count(comps, cond.group(1)) if cond else None
                rep.while_trips[op.name] = trips
                if body:
                    absorb(analyze_comp(body.group(1)), trips or 1)
                continue
            if kind in ("call", "conditional", "async-start", "async-done"):
                for target in re.findall(r"(?:calls|to_apply|branch_computations)=\{?%?([\w.\-,% ]+)\}?", op.rest):
                    for t in re.findall(r"[\w.\-]+", target):
                        if t in comps:
                            absorb(analyze_comp(t))
                continue
            # collectives (match base kind; e.g. all-reduce-start)
            base = next((c for c in _COLLECTIVES if kind.startswith(c)), None)
            if base is not None:
                g = group_size(op.rest, num_partitions)
                size = shape_bytes(op.type_str)
                if base == "all-reduce":
                    w = 2.0 * (g - 1) / max(g, 1) * size
                elif base == "all-gather":
                    w = (g - 1) / max(g, 1) * size
                elif base == "reduce-scatter":
                    w = float(g - 1) * size
                elif base == "all-to-all":
                    w = (g - 1) / max(g, 1) * size
                else:
                    w = float(size)
                wire += w
                ncoll += 1
                coll[base] = coll.get(base, 0.0) + w
                items.append((base, op.type_str.strip(), g, w))
                bytes_ += _op_bytes(op, comp, comps)
                continue
            if kind == "dot":
                dflops += _dot_flops(op, comp)
                bytes_ += _op_bytes(op, comp, comps)
                continue
            if kind == "fusion":
                m2 = re.search(r"calls=%([\w.\-]+)", op.rest)
                if m2 and m2.group(1) in comps:
                    inner = comps[m2.group(1)]
                    for iop in inner.ops:
                        if iop.kind == "dot":
                            dflops += _dot_flops(iop, inner)
                        elif iop.kind not in _SKIP_BYTES:
                            n = 1
                            for d in shape_dims(iop.type_str):
                                n *= d
                            fflops += n  # 1 flop/element estimate
                bytes_ += _op_bytes(op, comp, comps)
                continue
            if kind in _SKIP_BYTES:
                continue
            bytes_ += _op_bytes(op, comp, comps)
        memo[name] = (dflops, fflops, bytes_, wire, coll, ncoll, items)
        return memo[name]

    df, ff, b, w, c, n, items = analyze_comp(entry)
    rep.dot_flops = df
    rep.fusion_flops = ff
    rep.flops = df + ff
    rep.hbm_bytes = b
    rep.collective_wire_bytes = w
    rep.collective_breakdown = c
    rep.n_collectives = n
    # aggregate identical (kind, type, group) and keep the heaviest 12
    agg: dict = {}
    cnt: dict = {}
    for kind, ts, g, wb in items:
        key = (kind, ts, g)
        agg[key] = agg.get(key, 0.0) + wb
        cnt[key] = cnt.get(key, 0) + 1
    top = sorted(agg.items(), key=lambda kv: -kv[1])[:12]
    rep.top_collectives = [
        {"kind": k[0], "type": k[1][:60], "group": k[2], "wire_bytes": v,
         "count": cnt[k]}
        for k, v in top
    ]
    return rep
