"""Roofline terms from the HLO analysis (TPU v5e constants).

    compute term    = HLO_FLOPs_per_device   / peak_FLOPs (197 TF bf16)
    memory term     = HLO_bytes_per_device   / HBM bw     (819 GB/s)
    collective term = wire_bytes_per_device  / ICI link bw (~50 GB/s)

The analyzed HLO is the *partitioned* (per-device) module, so terms are
per-device by construction. MODEL_FLOPS uses 6*N*D (train) / 2*N*D
(inference) on *active* params plus explicit attention/SSM terms, giving
the "useful compute" ratio that catches remat and masked-block waste.
"""
from __future__ import annotations

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

__all__ = [
    "static_cost_terms",
    "roofline_terms",
    "model_flops_estimate",
    "PEAK_FLOPS",
    "HBM_BW",
    "ICI_BW",
]


def static_cost_terms(flops: float, hbm_bytes: float, wire_bytes: float) -> dict:
    """Roofline seconds + bottleneck for raw static counts.

    The shared table between the dry-run roofline (whole compiled
    program) and the tracecheck cost model (one while-body iteration):
    both divide the same three counters by the same hardware constants.
    """
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": wire_bytes / ICI_BW,
    }
    bottleneck = max(terms, key=terms.get)
    return {
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "step_time_lb_s": max(terms.values()),
    }


def roofline_terms(hlo_report, n_devices: int, model_flops: float | None = None) -> dict:
    out = static_cost_terms(
        hlo_report.flops, hlo_report.hbm_bytes, hlo_report.collective_wire_bytes
    )
    terms = {k: out[k] for k in ("compute_s", "memory_s", "collective_s")}
    if model_flops is not None and hlo_report.flops > 0:
        # useful-compute ratio: global model flops vs global compiled flops
        out["model_flops_ratio"] = model_flops / (hlo_report.flops * n_devices)
        out["mfu_upper_bound"] = model_flops / (
            max(terms.values()) * n_devices * PEAK_FLOPS
        )
    return out


def model_flops_estimate(cfg, cell) -> dict:
    """Analytic MODEL_FLOPS for this (arch x shape) cell (global, per step)."""
    B, S = cell.global_batch, cell.seq_len
    train = cell.step == "train"
    n_tokens = B * (S if cell.step != "decode" else 1)
    mult = 6 if train else 2
    n_active = cfg.n_active_params()
    dense = mult * n_active * n_tokens

    # attention score/value flops: 2 * 2 * B * S_q * S_kv_eff * H * dh per layer
    attn = 0.0
    n_attn = sum(1 for k in cfg.pattern() if k == "attn")
    H, dh = cfg.n_heads, cfg.d_head
    if cell.step == "decode":
        kv = min(S, cfg.sliding_window or S)
        attn = 4.0 * B * 1 * kv * H * dh * n_attn
    else:
        kv_eff = min(S, cfg.sliding_window or S)
        # causal: ~half the square (full square for encoders)
        frac = 1.0 if not cfg.causal else 0.5
        attn = 4.0 * B * S * kv_eff * frac * H * dh * n_attn
        if train:
            attn *= 3  # fwd + 2x bwd
    # SSD state flops: ~ (2*N*P*2) per token per head (state update + output)
    ssd = 0.0
    if cfg.ssm is not None:
        Hs = cfg.ssm.n_heads(cfg.d_model)
        n_ssm = sum(1 for k in cfg.pattern() if k == "ssm")
        ssd = 4.0 * n_tokens * Hs * cfg.ssm.d_state * cfg.ssm.head_dim * n_ssm
        if train:
            ssd *= 3
    total = dense + attn + ssd
    return {"dense": dense, "attn": attn, "ssd": ssd, "total": total}
