"""jax version compatibility shims.

The repo targets current jax but must keep running on the pinned
container version; everything version-dependent is funnelled through
here so call sites stay clean.
"""
from __future__ import annotations

import inspect

import jax

__all__ = ["shard_map"]


def shard_map(
    f,
    *,
    mesh,
    in_specs,
    out_specs,
    check_vma: bool = True,
    check_rep: bool | None = None,
    axis_names=None,
    auto=None,
):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes it as ``jax.shard_map`` with a ``check_vma`` flag
    and an ``axis_names`` manual-axes set; older releases only have
    ``jax.experimental.shard_map.shard_map`` whose equivalents are
    ``check_rep`` and ``auto`` (the *complement*: axes shard_map may
    auto-shard over). Both spellings are accepted here and translated to
    whatever the installed jax understands, so callers never drop a
    kwarg on the fallback branch:

    * ``check_rep`` is an alias for ``check_vma`` (the old name wins
      when both are given, matching legacy call sites).
    * ``axis_names`` (manual axes) and ``auto`` (automatic axes) are
      complements over ``mesh.axis_names``; whichever one the target
      signature lacks is derived from the other via the mesh.
    """
    if check_rep is not None:
        check_vma = check_rep

    if hasattr(jax, "shard_map"):
        target = jax.shard_map
        kwargs = {"check_vma": check_vma}
    else:
        from jax.experimental.shard_map import shard_map as target

        kwargs = {"check_rep": check_vma}

    params = inspect.signature(target).parameters
    if "check_vma" not in params and "check_vma" in kwargs:
        kwargs = {"check_rep": kwargs.pop("check_vma")}
    if "check_rep" not in params and "check_rep" in kwargs:
        kwargs = {"check_vma": kwargs.pop("check_rep")}

    mesh_axes = tuple(getattr(mesh, "axis_names", ()))
    if axis_names is None and auto is not None:
        axis_names = frozenset(mesh_axes) - frozenset(auto)
    if auto is None and axis_names is not None:
        auto = frozenset(mesh_axes) - frozenset(axis_names)
    # Only pass the manual/auto split when the caller asked for one AND
    # the target can express it; a full-manual default needs no kwarg.
    if axis_names is not None and frozenset(axis_names) != frozenset(mesh_axes):
        if "axis_names" in params:
            kwargs["axis_names"] = frozenset(axis_names)
        elif "auto" in params:
            kwargs["auto"] = frozenset(auto)
        else:
            raise TypeError(
                "this jax version's shard_map supports neither 'axis_names' "
                "nor 'auto'; cannot request a partial-manual region"
            )

    return target(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
