"""jax version compatibility shims.

The repo targets current jax but must keep running on the pinned
container version; everything version-dependent is funnelled through
here so call sites stay clean.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes it as ``jax.shard_map`` with a ``check_vma``
    flag; older releases only have ``jax.experimental.shard_map`` whose
    equivalent flag is ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _legacy

    return _legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma)
