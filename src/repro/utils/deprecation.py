"""Once-per-process deprecation warnings for the legacy shims.

Every deprecated surface (``core.solve`` re-exports, the
``core.feasibility`` drivers, ``ProblemLP``, ``core.mwu_dist``) funnels
through :func:`warn_once` so a long-running process — a serving engine,
a benchmark sweep — sees exactly one ``DeprecationWarning`` per shim,
not one per call.
"""
from __future__ import annotations

import threading
import warnings

__all__ = ["warn_once"]

_WARNED: set[str] = set()
_LOCK = threading.Lock()


def warn_once(key: str, message: str, stacklevel: int = 3) -> None:
    """Emit ``DeprecationWarning(message)`` the first time ``key`` is seen.

    Thread-safe: concurrent first calls with the same key (a serving
    engine warming workers through a shim) race on the seen-set, so the
    check-and-mark is done under a lock and exactly one thread warns.
    """
    with _LOCK:
        if key in _WARNED:
            return
        _WARNED.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)
